//! The sharded fleet engine: parallel intra-interval placement over
//! host partitions (pods/zones) behind a deterministic front-end router.
//!
//! The single-shard [`EventCore`] places every request of an interval
//! against one global [`crate::cluster::ClusterIndex`] on one thread;
//! parallelism before this layer existed only *across* sweep cells. The
//! [`ShardedCore`] partitions the fleet with a
//! [`crate::cluster::ShardMap`] into `S` shards — each owning its own
//! `EventCore`, i.e. its own index, activity counters, health state and
//! policy instance — and turns each interval into a fan-out/merge:
//!
//! 1. **Route.** The interval's batch is split by home shard
//!    (`vm.id % S`), preserving request order within each sub-batch.
//! 2. **Fan out.** Departure release and round-0 placement run on the
//!    per-shard cores concurrently over [`std::thread::scope`] workers
//!    pulling shards off an atomic work queue — the sweep runner's
//!    thread-count-independence idiom. Shards share nothing, so worker
//!    count and scheduling cannot change any per-shard outcome.
//! 3. **Merge + retry.** Decisions are merged back into request order
//!    (local GPU refs translated to global). A request *rejected* by
//!    its home shard with a retryable reason is then offered to the
//!    remaining shards in fixed order (`home+1, home+2, …` mod `S`) on
//!    the router thread; the first `Placed` (or `Queued`) wins, and a
//!    request every shard refuses keeps its home shard's verdict. The
//!    router uncounts the extra offers so the merged accounting keeps
//!    `sum(rejections) == requested − accepted` with one entry per
//!    request.
//!
//! **Determinism contract.** `shards == 1` is byte-identical to the
//! unsharded engine by construction: one core, the full batch in order,
//! the same seed, no retry offers, identity ref translation and the
//! same sample/availability formulas. For `shards > 1` every
//! cross-shard interaction (routing, retry order, merge order, the
//! rebalancer's pair order) is a pure function of the trace and the
//! shard count — worker threads only ever execute independent per-shard
//! work, so results are reproducible at any `threads` setting.
//!
//! The ops/fault layer generates one *global* schedule (identical at
//! every shard count) which [`ShardedCore::set_fault_schedule`] splits
//! by owning host into per-shard local-reference schedules. Cross-shard
//! consolidation is an opt-in periodic rebalance
//! ([`ShardedCore::set_rebalance`]) walking shard pairs in fixed order
//! under the existing [`MigrationBudget`], moving sole-tenant GIs onto
//! already-active GPUs of the receiving shard via
//! [`EventCore::transfer_out`]/[`EventCore::adopt`]. The donor-side
//! candidate heuristic is pluggable: [`ShardedCore::set_rebalance_planner`]
//! swaps the sole-tenant scan for any registry migration planner
//! (`defrag`, `consolidate`, `ilp-repair`, ...) consulted per shard
//! over a [`crate::migrate::PlanScope::Set`] of the donor's GPUs.

use super::event_core::EventCore;
use super::metrics::{acceptance_rate, Sample, SimResult};
use crate::cluster::vm::{Time, VmId, VmSpec, HOUR};
use crate::cluster::{DataCenter, GpuRef, Host, ShardMap};
use crate::mig::{Placement, NUM_MODELS, NUM_PROFILE_KEYS};
use crate::migrate::{
    MigrationBudget, MigrationEvent, MigrationKind, MigrationPlan, MigrationPlanner, PlanCtx,
    PlanScope, PlanStep, PlanTrigger,
};
use crate::ops::{FaultInjector, OpsEvent, QueueConfig};
use crate::policies::{
    probe_gpu, Decision, Policy, PolicyConfig, PolicyCtx, RejectCounts, RejectReason,
};
use crate::recover::OnCorruption;
use crate::util::codec::{Dec, Enc};
use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// The rebalance passes' receiver probe: the first already-active GPU
/// of `spec`'s model (ascending `GpuRef`) that can host it, read off
/// the index's per-model schedulable set instead of a full host walk.
/// Identical to the old fleet scan: `probe_gpu` failed for exactly the
/// unschedulable or model-incompatible GPUs the walk still visited, and
/// both candidate orders ascend.
fn first_active_fit(dc: &DataCenter, spec: &VmSpec) -> Option<(GpuRef, Placement)> {
    for to in dc.index().schedulable(spec.profile.model()) {
        if dc.gpu(to).is_empty() {
            continue; // only consolidate onto active GPUs
        }
        if let Some(p) = probe_gpu(dc, spec, to) {
            return Some((to, p));
        }
    }
    None
}

/// Per-shard policy-context seed: shard 0 keeps the run seed unchanged
/// (the `shards == 1` identity), later shards split off their own
/// streams with a golden-ratio mix.
pub fn shard_seed(seed: u64, shard: usize) -> u64 {
    if shard == 0 {
        seed
    } else {
        seed ^ (shard as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
    }
}

/// The sharded interval engine: router + per-shard [`EventCore`]s.
/// Mirrors the `EventCore` driving surface (`release_due` /
/// `place_merged` / `close_interval` / `run_until` / `into_result`) so
/// both the simulator loop and coordinator-style callers can drive it.
pub struct ShardedCore {
    map: ShardMap,
    cores: Vec<EventCore>,
    /// Fan-out worker cap (≥ 1; `new` resolves 0 to the machine's
    /// available parallelism). Affects wall-clock only, never results.
    threads: usize,
    /// Index of the open (not yet closed) interval.
    hour: u64,
    /// Router-side offer corrections: a retried request was counted as
    /// `requested` (and possibly rejected) by every shard that saw it;
    /// these counters uncount all but one entry per request.
    extra_requested: u64,
    extra_per_profile: [u64; NUM_PROFILE_KEYS],
    extra_rejections: RejectCounts,
    /// The latest batch's merged decisions, in request order, with
    /// global GPU references.
    merged: Vec<Decision>,
    samples: Vec<Sample>,
    /// Global migration log: per-shard events translated to global
    /// references as they appear, plus the rebalancer's cross-shard
    /// moves, in deterministic merge order.
    migrations: Vec<MigrationEvent>,
    mig_cursor: Vec<usize>,
    /// Cross-shard rebalance period in intervals (`None` = off).
    rebalance_every: Option<u64>,
    budget: MigrationBudget,
    /// Per-shard planner instances consulted by the rebalance pass
    /// (`None` = the built-in sole-tenant scan). One instance per shard
    /// so each consult is a pure function of that shard's state.
    rebalance_planners: Option<Vec<Box<dyn MigrationPlanner>>>,
    /// Per-VM move tally for `budget.max_moves_per_vm`.
    moves_per_vm: HashMap<VmId, u32>,
    /// Specs of VMs placed through the router — the rebalancer must
    /// re-place a transferred VM from its full spec. Maintained only
    /// while rebalancing is enabled.
    specs: HashMap<VmId, VmSpec>,
    /// Reusable per-shard routing scratch: sub-batches and the original
    /// batch index of each routed request.
    route_scratch: Vec<Vec<VmSpec>>,
    slot_scratch: Vec<Vec<usize>>,
}

impl ShardedCore {
    /// Build over `hosts` split into `shards` partitions, with one
    /// policy instance per shard (instances must be identically
    /// configured; the registry builds them). `threads == 0` resolves
    /// to the machine's available parallelism.
    pub fn new(
        hosts: &[Host],
        policies: Vec<Box<dyn Policy>>,
        seed: u64,
        shards: usize,
        threads: usize,
    ) -> ShardedCore {
        let map = ShardMap::new(hosts.len(), shards);
        assert_eq!(policies.len(), map.shards(), "one policy per shard");
        let cores: Vec<EventCore> = map
            .split_hosts(hosts)
            .into_iter()
            .zip(policies)
            .enumerate()
            .map(|(s, (local_hosts, policy))| {
                EventCore::new(DataCenter::new(local_hosts), policy, PolicyCtx::new(shard_seed(seed, s)))
            })
            .collect();
        let n = cores.len();
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            threads
        };
        ShardedCore {
            map,
            cores,
            threads,
            hour: 0,
            extra_requested: 0,
            extra_per_profile: [0; NUM_PROFILE_KEYS],
            extra_rejections: [0; 6],
            merged: Vec::new(),
            samples: Vec::new(),
            migrations: Vec::new(),
            mig_cursor: vec![0; n],
            rebalance_every: None,
            budget: MigrationBudget::unlimited(),
            rebalance_planners: None,
            moves_per_vm: HashMap::new(),
            specs: HashMap::new(),
            route_scratch: (0..n).map(|_| Vec::new()).collect(),
            slot_scratch: (0..n).map(|_| Vec::new()).collect(),
        }
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.cores.len()
    }

    /// The host partition.
    pub fn map(&self) -> &ShardMap {
        &self.map
    }

    /// Read access to the per-shard cores (integrity checks in tests).
    pub fn shards(&self) -> &[EventCore] {
        &self.cores
    }

    pub fn set_integrity_every(&mut self, every: u64) {
        for c in &mut self.cores {
            c.set_integrity_every(every);
        }
    }

    /// Propagate the `--on-corruption` action to every shard (see
    /// [`EventCore::set_on_corruption`]).
    pub fn set_on_corruption(&mut self, action: OnCorruption) {
        for c in &mut self.cores {
            c.set_on_corruption(action);
        }
    }

    /// Configure admission queueing on every shard. Each shard parks
    /// and retries its own home requests; capacities are per shard.
    pub fn set_admission_queue(&mut self, cfg: QueueConfig) {
        for c in &mut self.cores {
            c.set_admission_queue(cfg);
        }
    }

    /// Install a *global* fault/maintenance schedule, split by owning
    /// host into per-shard local-reference schedules. Generating the
    /// schedule over the whole fleet keeps the fault stream identical
    /// at every shard count; with one shard the split is an identity.
    pub fn set_fault_schedule(&mut self, injector: FaultInjector) {
        let (schedule, ban_after) = injector.into_parts();
        let mut per: Vec<Vec<(Time, OpsEvent)>> = (0..self.cores.len()).map(|_| Vec::new()).collect();
        for (t, ev) in schedule {
            let (s, local) = match ev {
                OpsEvent::GpuFail { gpu, until } => {
                    let s = self.map.shard_of_host(gpu.host);
                    (s, OpsEvent::GpuFail { gpu: self.map.to_local(s, gpu), until })
                }
                OpsEvent::GpuRepair { gpu } => {
                    let s = self.map.shard_of_host(gpu.host);
                    (s, OpsEvent::GpuRepair { gpu: self.map.to_local(s, gpu) })
                }
                OpsEvent::HostFail { host, until } => {
                    let s = self.map.shard_of_host(host);
                    (s, OpsEvent::HostFail { host: host - self.map.base(s), until })
                }
                OpsEvent::HostRepair { host } => {
                    let s = self.map.shard_of_host(host);
                    (s, OpsEvent::HostRepair { host: host - self.map.base(s) })
                }
                OpsEvent::DrainStart { host, until } => {
                    let s = self.map.shard_of_host(host);
                    (s, OpsEvent::DrainStart { host: host - self.map.base(s), until })
                }
                OpsEvent::DrainDone { host } => {
                    let s = self.map.shard_of_host(host);
                    (s, OpsEvent::DrainDone { host: host - self.map.base(s) })
                }
                // Log-only event emitted by the on-corruption repair
                // path — never part of a generated schedule.
                OpsEvent::StateRepair { .. } => continue,
            };
            per[s].push((t, local));
        }
        for (core, events) in self.cores.iter_mut().zip(per) {
            // Filtering a sorted schedule keeps each part sorted.
            core.set_fault_schedule(FaultInjector::new(events, ban_after));
        }
    }

    /// Enable cross-shard consolidation every `every` intervals under
    /// `budget`. Off by default — the fan-out/merge path alone is the
    /// `shards == 1` byte-identity surface.
    pub fn set_rebalance(&mut self, every: u64, budget: MigrationBudget) {
        self.rebalance_every = if every == 0 { None } else { Some(every) };
        self.budget = budget;
    }

    /// Swap the rebalancer's donor-selection heuristic for a registry
    /// migration planner (see [`crate::policies::PLANNER_NAMES`]); its
    /// `Migrate` steps become the evacuation nominations the router
    /// tries against the other shards. Builds one planner instance per
    /// shard from `cfg`. Returns `false` (and changes nothing) for an
    /// unknown name.
    pub fn set_rebalance_planner(&mut self, name: &str, cfg: &PolicyConfig) -> bool {
        let planners: Option<Vec<Box<dyn MigrationPlanner>>> = (0..self.cores.len())
            .map(|_| crate::policies::planned::planner_from_name(name, cfg))
            .collect();
        let known = planners.is_some();
        if known {
            self.rebalance_planners = planners;
        }
        known
    }

    /// Pre-size per-shard collections from trace metadata (requests are
    /// spread across shards by routing; each shard reserves its share).
    pub fn reserve_for_trace(&mut self, requests: usize, intervals: u64) {
        let per_shard = requests / self.cores.len() + 1;
        for c in &mut self.cores {
            c.reserve_for_trace(per_shard, intervals);
        }
        self.samples.reserve(intervals as usize);
        self.migrations.reserve(requests / 32 + 1);
    }

    pub fn interval(&self) -> Time {
        self.cores[0].interval()
    }

    /// Index of the open interval.
    pub fn hour(&self) -> u64 {
        self.hour
    }

    /// End time of the open interval.
    pub fn interval_end(&self) -> Time {
        (self.hour + 1) * self.interval()
    }

    /// The interval that owns an arrival at `t` (the [`EventCore`]
    /// convention).
    pub fn window_of(&self, t: Time) -> u64 {
        self.cores[0].window_of(t)
    }

    pub fn pending_departures(&self) -> usize {
        self.cores.iter().map(|c| c.pending_departures()).sum()
    }

    /// Requests seen, cluster-level (each request once, however many
    /// shards it was offered to).
    pub fn requested(&self) -> u64 {
        self.cores.iter().map(|c| c.requested()).sum::<u64>() - self.extra_requested
    }

    pub fn accepted(&self) -> u64 {
        self.cores.iter().map(|c| c.accepted()).sum()
    }

    /// Merged per-reason rejections; sums to `requested() - accepted()`.
    pub fn rejections(&self) -> RejectCounts {
        let mut out = [0u64; 6];
        for c in &self.cores {
            for (o, r) in out.iter_mut().zip(c.rejections()) {
                *o += r;
            }
        }
        for (o, e) in out.iter_mut().zip(self.extra_rejections) {
            *o -= e;
        }
        out
    }

    /// VMs evicted by hardware failures so far, fleet-wide.
    pub fn interrupted(&self) -> u64 {
        self.cores.iter().map(|c| c.interrupted()).sum()
    }

    /// Requests parked across all shard queues.
    pub fn queue_len(&self) -> usize {
        self.cores.iter().map(|c| c.queue_len()).sum()
    }

    /// The merged global migration log so far.
    pub fn migration_events(&self) -> &[MigrationEvent] {
        &self.migrations
    }

    /// The latest batch's merged decisions, in request order, with
    /// global GPU references.
    pub fn decisions(&self) -> &[Decision] {
        &self.merged
    }

    /// Run `work` once per shard. With more than one worker the shards
    /// are pulled off an atomic queue by scoped threads — each shard is
    /// still processed exactly once by exactly one worker, so the
    /// per-shard outcomes cannot depend on the worker count.
    fn for_each_shard(&mut self, work: impl Fn(&mut EventCore) + Sync) {
        let workers = self.threads.min(self.cores.len()).max(1);
        if workers <= 1 {
            for c in &mut self.cores {
                work(c);
            }
            return;
        }
        let cells: Vec<Mutex<Option<&mut EventCore>>> =
            self.cores.iter_mut().map(|c| Mutex::new(Some(c))).collect();
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cells.len() {
                        break;
                    }
                    let core = cells[i].lock().unwrap().take().expect("each shard taken once");
                    work(core);
                });
            }
        });
    }

    /// Release departures and replay due operational events on every
    /// shard (concurrently — shards share nothing).
    pub fn release_due(&mut self, t: Time) {
        self.for_each_shard(|core| core.release_due(t));
    }

    /// Round-0 placement: each shard places its routed sub-batch.
    fn fan_out_place(&mut self) {
        let workers = self.threads.min(self.cores.len()).max(1);
        if workers <= 1 {
            for (c, batch) in self.cores.iter_mut().zip(&self.route_scratch) {
                c.place_buffered(batch);
            }
            return;
        }
        let cells: Vec<Mutex<Option<(&mut EventCore, &[VmSpec])>>> = self
            .cores
            .iter_mut()
            .zip(&self.route_scratch)
            .map(|(c, b)| Mutex::new(Some((c, b.as_slice()))))
            .collect();
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cells.len() {
                        break;
                    }
                    let (core, batch) =
                        cells[i].lock().unwrap().take().expect("each shard taken once");
                    core.place_buffered(batch);
                });
            }
        });
    }

    /// Place the interval's batch: route by home shard, fan out, merge
    /// decisions back into request order, then run the fixed-order
    /// retry chain for retryable home rejections. Decisions (global
    /// refs) are readable via [`ShardedCore::decisions`] until the next
    /// batch. Callable several times per interval (coordinator-style);
    /// each shard's queue pass still runs once per interval.
    pub fn place_merged(&mut self, batch: &[VmSpec]) {
        let n = self.cores.len();
        for sub in &mut self.route_scratch {
            sub.clear();
        }
        for sub in &mut self.slot_scratch {
            sub.clear();
        }
        for (i, vm) in batch.iter().enumerate() {
            let s = self.map.home_shard(vm.id);
            self.route_scratch[s].push(*vm);
            self.slot_scratch[s].push(i);
        }
        self.fan_out_place();
        // Merge round-0 decisions into request order, translating
        // placed refs to the global namespace. Copied out first: the
        // retry offers below clobber the per-shard decision buffers.
        self.merged.clear();
        self.merged.resize(batch.len(), Decision::Rejected(RejectReason::NoGpuFit));
        for s in 0..n {
            let decisions = self.cores[s].decisions().to_vec();
            debug_assert_eq!(decisions.len(), self.slot_scratch[s].len());
            for (d, &slot) in decisions.iter().zip(&self.slot_scratch[s]) {
                self.merged[slot] = self.globalize(s, *d);
            }
        }
        if n > 1 {
            self.retry_rejections(batch);
        }
        if self.rebalance_every.is_some() {
            for (vm, d) in batch.iter().zip(&self.merged) {
                if d.is_placed() {
                    self.specs.insert(vm.id, *vm);
                }
            }
        }
        self.merge_migrations();
    }

    /// Offer each retryable home rejection to the other shards in fixed
    /// order; runs serially on the router thread in request order, so
    /// the outcome is independent of the fan-out workers.
    fn retry_rejections(&mut self, batch: &[VmSpec]) {
        let n = self.cores.len();
        for (i, vm) in batch.iter().enumerate() {
            let Some(home_reason) = self.merged[i].reject_reason() else { continue };
            if !home_reason.retryable() {
                continue;
            }
            // Reasons of every rejected offer so far (home first).
            let mut chain = vec![home_reason];
            let mut settled = false;
            for hop in 1..n {
                let s = (self.map.home_shard(vm.id) + hop) % n;
                self.cores[s].place_buffered(std::slice::from_ref(vm));
                let d = self.cores[s].decisions()[0];
                match d {
                    Decision::Placed { .. } => {
                        self.merged[i] = self.globalize(s, d);
                        settled = true;
                    }
                    // A shard with queueing parked the request — that
                    // terminates the chain (it will retry *there*).
                    Decision::Rejected(RejectReason::Queued) => {
                        self.merged[i] = d;
                        settled = true;
                    }
                    Decision::Rejected(r) => chain.push(r),
                }
                if settled {
                    // The winning offer stands; uncount every earlier
                    // rejected offer (the home shard's included).
                    self.extra_requested += chain.len() as u64;
                    self.extra_per_profile[vm.profile.dense()] += chain.len() as u64;
                    for r in &chain {
                        self.extra_rejections[r.index()] += 1;
                    }
                    break;
                }
            }
            if !settled {
                // Every shard refused: the home verdict stands; uncount
                // the other shards' offers.
                self.extra_requested += (chain.len() - 1) as u64;
                self.extra_per_profile[vm.profile.dense()] += (chain.len() - 1) as u64;
                for r in &chain[1..] {
                    self.extra_rejections[r.index()] += 1;
                }
            }
        }
    }

    /// Translate a shard-local decision to global references.
    fn globalize(&self, s: usize, d: Decision) -> Decision {
        match d {
            Decision::Placed { gpu, placement } => {
                Decision::Placed { gpu: self.map.to_global(s, gpu), placement }
            }
            Decision::Rejected(_) => d,
        }
    }

    /// Append each shard's newly recorded migrations to the global log
    /// (ascending shard order, per-shard event order), translating refs.
    fn merge_migrations(&mut self) {
        for s in 0..self.cores.len() {
            let events = self.cores[s].migration_events();
            for ev in &events[self.mig_cursor[s]..] {
                self.migrations.push(MigrationEvent {
                    vm: ev.vm,
                    from: self.map.to_global(s, ev.from),
                    to: self.map.to_global(s, ev.to),
                    kind: ev.kind,
                    model: ev.model,
                    blocks: ev.blocks,
                });
            }
            self.mig_cursor[s] = self.cores[s].migration_events().len();
        }
    }

    /// Cross-shard consolidation pass (the sharded analogue of a
    /// `PlanScope::Set` plan per shard pair): walk (donor, receiver)
    /// pairs in fixed order; move sole-tenant GIs (ascending donor
    /// `GpuRef`) onto the receiver's first already-active fitting GPU,
    /// under the interval/per-VM budget. Runs on the router thread.
    fn rebalance(&mut self) {
        let n = self.cores.len();
        if n < 2 || self.budget.max_moves_per_interval == 0 {
            return;
        }
        if self.rebalance_planners.is_some() {
            self.rebalance_planned();
            return;
        }
        let mut moved = 0u32;
        'pairs: for donor in 0..n {
            for receiver in 0..n {
                if donor == receiver {
                    continue;
                }
                // Donor candidates: GPUs hosting exactly one instance —
                // emptying one switches hardware off (Eq. 4's goal).
                let mut donors: Vec<(GpuRef, VmId)> = Vec::new();
                for h in self.cores[donor].dc.hosts() {
                    for (g, gpu) in h.gpus().iter().enumerate() {
                        if gpu.instances().len() == 1 {
                            donors.push((
                                GpuRef { host: h.id, gpu: g as u8 },
                                gpu.instances()[0].vm,
                            ));
                        }
                    }
                }
                for (from_local, vm_id) in donors {
                    if moved >= self.budget.max_moves_per_interval {
                        break 'pairs;
                    }
                    // Queue-served VMs were never routed through the
                    // router's spec log — skip them (best effort).
                    let Some(spec) = self.specs.get(&vm_id).copied() else { continue };
                    if self.moves_per_vm.get(&vm_id).copied().unwrap_or(0)
                        >= self.budget.max_moves_per_vm
                    {
                        continue;
                    }
                    let target = first_active_fit(&self.cores[receiver].dc, &spec);
                    let Some((to_local, placement)) = target else { continue };
                    if self.cores[donor].transfer_out(vm_id).is_none() {
                        continue;
                    }
                    self.cores[receiver].adopt(&spec, to_local, placement);
                    *self.moves_per_vm.entry(vm_id).or_insert(0) += 1;
                    moved += 1;
                    self.migrations.push(MigrationEvent {
                        vm: vm_id,
                        from: self.map.to_global(donor, from_local),
                        to: self.map.to_global(receiver, to_local),
                        kind: MigrationKind::Inter,
                        model: spec.profile.model(),
                        blocks: spec.profile.size(),
                    });
                }
            }
        }
    }

    /// Planner-driven rebalance: each donor shard's registry planner is
    /// consulted over the donor's full GPU set (`PlanScope::Set` — the
    /// per-shard analogue of a tick round), and every `Migrate` step it
    /// proposes is reinterpreted as an *evacuation nomination*: the
    /// named VM is offered to the other shards' already-active GPUs in
    /// fixed order (`donor+1, donor+2, …` mod `S`) instead of moving
    /// inside the donor. `Repack` steps are intra-shard concerns the
    /// cross-shard pass skips; a nomination nothing can host simply
    /// stays put. Runs serially on the router thread, so the pass is a
    /// pure function of the shard states and the consult order.
    fn rebalance_planned(&mut self) {
        let n = self.cores.len();
        let now = (self.hour + 1) * self.interval();
        let mut moved = 0u32;
        let mut plan = MigrationPlan::new();
        'donors: for donor in 0..n {
            let scope: BTreeSet<GpuRef> = self.cores[donor].dc.gpu_refs().into_iter().collect();
            plan.clear();
            let ctx = PlanCtx {
                now,
                trigger: PlanTrigger::Tick,
                scope: PlanScope::Set(&scope),
                pending: &[],
            };
            let planners = self.rebalance_planners.as_mut().expect("checked by rebalance");
            planners[donor].plan(&self.cores[donor].dc, &ctx, &mut plan);
            for step in plan.steps() {
                let PlanStep::Migrate { vm, from, .. } = step else { continue };
                let (vm_id, from_local) = (*vm, *from);
                if moved >= self.budget.max_moves_per_interval {
                    break 'donors;
                }
                // Queue-served VMs were never routed through the
                // router's spec log — skip them (best effort).
                let Some(spec) = self.specs.get(&vm_id).copied() else { continue };
                if self.moves_per_vm.get(&vm_id).copied().unwrap_or(0)
                    >= self.budget.max_moves_per_vm
                {
                    continue;
                }
                let target = (1..n).find_map(|hop| {
                    let receiver = (donor + hop) % n;
                    first_active_fit(&self.cores[receiver].dc, &spec)
                        .map(|(to, p)| (receiver, to, p))
                });
                let Some((receiver, to_local, placement)) = target else { continue };
                if self.cores[donor].transfer_out(vm_id).is_none() {
                    continue; // the nominated VM already departed
                }
                self.cores[receiver].adopt(&spec, to_local, placement);
                *self.moves_per_vm.entry(vm_id).or_insert(0) += 1;
                moved += 1;
                self.migrations.push(MigrationEvent {
                    vm: vm_id,
                    from: self.map.to_global(donor, from_local),
                    to: self.map.to_global(receiver, to_local),
                    kind: MigrationKind::Inter,
                    model: spec.profile.model(),
                    blocks: spec.profile.size(),
                });
            }
        }
    }

    /// Close the open interval on every shard (tick, sample, integrity)
    /// and take the merged cluster-level sample. Runs the optional
    /// cross-shard rebalance first, on its period.
    pub fn close_interval(&mut self) {
        if let Some(every) = self.rebalance_every {
            if (self.hour + 1) % every == 0 {
                self.rebalance();
            }
        }
        self.for_each_shard(|core| core.close_interval());
        self.merge_migrations();
        let mut active = 0usize;
        let mut total = 0usize;
        let mut resident = 0usize;
        for c in &self.cores {
            let (a, t) = c.dc.active_hardware();
            active += a;
            total += t;
            resident += c.dc.resident_count();
        }
        let active_rate = if total == 0 { 0.0 } else { active as f64 / total as f64 };
        self.samples.push(Sample {
            hour: self.hour,
            active_rate,
            acceptance_rate: acceptance_rate(self.accepted(), self.requested()),
            resident,
        });
        self.hour += 1;
    }

    /// One full interval: departures + ops, routed placement, tick and
    /// merged sample — the sharded [`EventCore::step_buffered`].
    pub fn step_buffered(&mut self, batch: &[VmSpec]) {
        self.release_due(self.interval_end());
        self.place_merged(batch);
        self.close_interval();
    }

    /// Run empty intervals until `window` is the open interval.
    pub fn run_until(&mut self, window: u64) {
        while self.hour < window {
            self.step_buffered(&[]);
        }
    }

    /// Serialize the whole sharded engine — router accounting plus one
    /// [`EventCore::snapshot_bytes`] image per shard — with the same
    /// determinism contract: encoding a state and encoding the state
    /// restored from it yield identical bytes. Taken at an interval
    /// boundary (after [`ShardedCore::close_interval`]); the transient
    /// per-batch buffers (`merged`, routing scratch) are intentionally
    /// not part of the image.
    pub fn snapshot_bytes(&self) -> Vec<u8> {
        let mut e = Enc::with_capacity(1024 * self.cores.len());
        e.usize(self.map.num_hosts());
        e.usize(self.cores.len());
        for c in &self.cores {
            e.blob(&c.snapshot_bytes());
        }
        e.u64(self.hour);
        e.u64(self.extra_requested);
        for x in self.extra_per_profile {
            e.u64(x);
        }
        for x in self.extra_rejections {
            e.u64(x);
        }
        e.usize(self.samples.len());
        for s in &self.samples {
            e.u64(s.hour);
            e.f64(s.active_rate);
            e.f64(s.acceptance_rate);
            e.usize(s.resident);
        }
        e.usize(self.migrations.len());
        for ev in &self.migrations {
            ev.encode(&mut e);
        }
        for &c in &self.mig_cursor {
            e.usize(c);
        }
        e.opt_u64(self.rebalance_every);
        e.u32(self.budget.max_moves_per_interval);
        e.u32(self.budget.max_moves_per_vm);
        let mut moves: Vec<(VmId, u32)> =
            self.moves_per_vm.iter().map(|(vm, n)| (*vm, *n)).collect();
        moves.sort_unstable();
        e.usize(moves.len());
        for (vm, n) in moves {
            e.u64(vm);
            e.u32(n);
        }
        let mut specs: Vec<&VmSpec> = self.specs.values().collect();
        specs.sort_unstable_by_key(|s| s.id);
        e.usize(specs.len());
        for s in specs {
            s.encode(&mut e);
        }
        match &self.rebalance_planners {
            None => e.bool(false),
            Some(ps) => {
                e.bool(true);
                e.usize(ps.len());
                for p in ps {
                    let mut state = Vec::new();
                    p.snapshot_state(&mut state);
                    e.blob(&state);
                }
            }
        }
        e.into_bytes()
    }

    /// Rebuild a [`ShardedCore`] from [`ShardedCore::snapshot_bytes`].
    /// The caller supplies what is configuration, not state: one policy
    /// instance per shard (same registry build as the original run —
    /// each shard's image verifies the policy name), the worker-thread
    /// cap (wall-clock only) and, when the run used a planner-driven
    /// rebalancer, fresh per-shard planner instances whose mutable state
    /// the snapshot then restores. Supplying planners for a snapshot
    /// that carries no planner state keeps them fresh (a config change
    /// on resume); the reverse is an error.
    pub fn restore_bytes(
        bytes: &[u8],
        policies: Vec<Box<dyn Policy>>,
        threads: usize,
        rebalance_planners: Option<Vec<Box<dyn MigrationPlanner>>>,
    ) -> Result<ShardedCore, String> {
        let mut d = Dec::new(bytes);
        let num_hosts = d.usize()?;
        let shards = d.count(9)?;
        if policies.len() != shards {
            return Err(format!(
                "snapshot holds {shards} shards but {} policies were supplied",
                policies.len()
            ));
        }
        let mut cores = Vec::with_capacity(shards);
        for policy in policies {
            cores.push(EventCore::restore_bytes(d.blob()?, policy)?);
        }
        let hour = d.u64()?;
        let extra_requested = d.u64()?;
        let mut extra_per_profile = [0u64; NUM_PROFILE_KEYS];
        for x in &mut extra_per_profile {
            *x = d.u64()?;
        }
        let mut extra_rejections = [0u64; 6];
        for x in &mut extra_rejections {
            *x = d.u64()?;
        }
        let n = d.count(32)?;
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n {
            samples.push(Sample {
                hour: d.u64()?,
                active_rate: d.f64()?,
                acceptance_rate: d.f64()?,
                resident: d.usize()?,
            });
        }
        let n = d.count(21)?;
        let mut migrations = Vec::with_capacity(n);
        for _ in 0..n {
            migrations.push(MigrationEvent::decode(&mut d)?);
        }
        let mut mig_cursor = Vec::with_capacity(shards);
        for _ in 0..shards {
            mig_cursor.push(d.usize()?);
        }
        let rebalance_every = d.opt_u64()?;
        let budget = MigrationBudget {
            max_moves_per_interval: d.u32()?,
            max_moves_per_vm: d.u32()?,
        };
        let n = d.count(12)?;
        let mut moves_per_vm = HashMap::with_capacity(n);
        for _ in 0..n {
            let vm = d.u64()?;
            moves_per_vm.insert(vm, d.u32()?);
        }
        let n = d.count(41)?;
        let mut specs = HashMap::with_capacity(n);
        for _ in 0..n {
            let spec = VmSpec::decode(&mut d)?;
            specs.insert(spec.id, spec);
        }
        let rebalance_planners = if d.bool()? {
            let n = d.count(8)?;
            let Some(mut planners) = rebalance_planners else {
                return Err(
                    "snapshot carries rebalance-planner state but no planners were supplied"
                        .into(),
                );
            };
            if planners.len() != n {
                return Err(format!(
                    "snapshot holds {n} planner states but {} planners were supplied",
                    planners.len()
                ));
            }
            for p in planners.iter_mut() {
                p.restore_state(d.blob()?)?;
            }
            Some(planners)
        } else {
            rebalance_planners
        };
        if !d.is_empty() {
            return Err("trailing bytes in sharded-core snapshot".into());
        }
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            threads
        };
        let n = cores.len();
        Ok(ShardedCore {
            map: ShardMap::new(num_hosts, shards),
            cores,
            threads,
            hour,
            extra_requested,
            extra_per_profile,
            extra_rejections,
            merged: Vec::new(),
            samples,
            migrations,
            mig_cursor,
            rebalance_every,
            budget,
            rebalance_planners,
            moves_per_vm,
            specs,
            route_scratch: (0..n).map(|_| Vec::new()).collect(),
            slot_scratch: (0..n).map(|_| Vec::new()).collect(),
        })
    }

    /// Finish: merge every shard's result into one cluster-level
    /// [`SimResult`] (offer corrections applied, queue leftovers
    /// flushed per shard, one global availability denominator).
    pub fn into_result(self, wall_seconds: f64) -> SimResult {
        let ShardedCore {
            cores,
            samples,
            migrations,
            extra_requested,
            extra_per_profile,
            extra_rejections,
            ..
        } = self;
        let mut avail = 0u64;
        let mut total = 0u64;
        for c in &cores {
            let (a, t) = c.availability_counters();
            avail += a;
            total += t;
        }
        let availability = if total == 0 { 1.0 } else { avail as f64 / total as f64 };
        let mut policy = String::new();
        let mut requested = 0u64;
        let mut accepted = 0u64;
        let mut per_profile = [(0u64, 0u64); NUM_PROFILE_KEYS];
        let mut rejections = [0u64; 6];
        let mut gpus_by_model = [0usize; NUM_MODELS];
        let mut gpu_activity = [(0u64, 0u64); NUM_MODELS];
        let mut interrupted = 0u64;
        let mut preempted = 0u64;
        let mut queue_delays = Vec::new();
        let mut gap_samples = Vec::new();
        for (s, core) in cores.into_iter().enumerate() {
            let r = core.into_result(0.0);
            if s == 0 {
                policy = r.policy;
            }
            requested += r.requested;
            accepted += r.accepted;
            for (acc, x) in per_profile.iter_mut().zip(r.per_profile) {
                acc.0 += x.0;
                acc.1 += x.1;
            }
            for (acc, x) in rejections.iter_mut().zip(r.rejections) {
                *acc += x;
            }
            for (acc, x) in gpus_by_model.iter_mut().zip(r.gpus_by_model) {
                *acc += x;
            }
            for (acc, x) in gpu_activity.iter_mut().zip(r.gpu_activity) {
                acc.0 += x.0;
                acc.1 += x.1;
            }
            interrupted += r.interrupted;
            preempted += r.preempted;
            queue_delays.extend(r.queue_delays);
            // Ascending shard order keeps the merged sample stream
            // deterministic (samples carry no timestamps of their own).
            gap_samples.extend(r.gap_samples);
        }
        requested -= extra_requested;
        for (acc, e) in per_profile.iter_mut().zip(extra_per_profile) {
            acc.0 -= e;
        }
        for (acc, e) in rejections.iter_mut().zip(extra_rejections) {
            *acc -= e;
        }
        SimResult {
            policy,
            samples,
            requested,
            accepted,
            per_profile,
            rejections,
            migration_events: migrations,
            gpus_by_model,
            gpu_activity,
            interrupted,
            preempted,
            queue_delays,
            availability,
            gap_samples,
            wall_seconds,
        }
    }
}

impl super::engine::IntervalCounters for ShardedCore {
    fn interval_record(&self, closed_hour: u64) -> crate::recover::IntervalRecord {
        crate::recover::IntervalRecord {
            hour: closed_hour,
            requested: self.requested(),
            accepted: self.accepted(),
            rejections: self.rejections(),
            migrations: self.migrations.len() as u64,
            interrupted: self.interrupted(),
            queue_len: self.queue_len() as u64,
        }
    }
}

/// Engine knobs specific to the sharded run, on top of the single-shard
/// [`super::SimulationOptions`].
#[derive(Debug, Clone)]
pub struct ShardOptions {
    /// Number of shards (clamped to the fleet size; 1 = the byte-
    /// identical single-shard configuration through the router).
    pub shards: usize,
    /// Fan-out worker cap (0 = available parallelism). Wall-clock only.
    pub threads: usize,
    /// Per-shard policy-context seed base (the unsharded `PolicyCtx`
    /// seed; shard 0 uses it unchanged).
    pub seed: u64,
    /// Cross-shard rebalance period in intervals (0 = off).
    pub rebalance_every: u64,
    /// Budget for the cross-shard rebalancer.
    pub budget: MigrationBudget,
    /// Registry planner name driving the rebalancer's evacuation
    /// nominations (`None` = the built-in sole-tenant scan). See
    /// [`ShardedCore::set_rebalance_planner`].
    pub rebalance_planner: Option<String>,
}

impl Default for ShardOptions {
    fn default() -> Self {
        ShardOptions {
            shards: 1,
            threads: 0,
            seed: 0,
            rebalance_every: 0,
            budget: MigrationBudget::unlimited(),
            rebalance_planner: None,
        }
    }
}

/// A configured sharded simulation run: the [`super::Simulation`] trace
/// loop over a [`ShardedCore`].
pub struct ShardedSimulation<'a> {
    pub hosts: &'a [Host],
    /// One policy instance per shard (identically configured).
    pub policies: Vec<Box<dyn Policy>>,
    pub vms: &'a [VmSpec],
    pub options: super::SimulationOptions,
    pub shard_options: ShardOptions,
    /// Configuration used to resolve `shard_options.rebalance_planner`
    /// through the planner registry (the ILP knobs ride here).
    pub planner_config: PolicyConfig,
}

impl<'a> ShardedSimulation<'a> {
    pub fn new(
        hosts: &'a [Host],
        policies: Vec<Box<dyn Policy>>,
        vms: &'a [VmSpec],
    ) -> ShardedSimulation<'a> {
        ShardedSimulation {
            hosts,
            policies,
            vms,
            options: super::SimulationOptions::default(),
            shard_options: ShardOptions::default(),
            planner_config: PolicyConfig::new(),
        }
    }

    /// Run to completion and collect merged metrics. Mirrors
    /// [`super::Simulation::run`] interval for interval: the same trace
    /// slicing, the same stop conditions, the same ops wiring (with the
    /// fault schedule drawn over the *global* fleet before splitting).
    pub fn run(self) -> SimResult {
        use crate::recover::{Checkpointer, SnapshotKind};
        use crate::sim::engine::IntervalCounters as _;

        let t_start = std::time::Instant::now();
        let so = self.shard_options;
        let last_arrival = self.vms.last().map(|v| v.arrival).unwrap_or(0);
        let resume = self.options.load_resume_image(SnapshotKind::Sharded);
        let resume_hour = resume.as_ref().map(|(h, _)| *h);
        let mut core = match resume {
            Some((_, payload)) => {
                // Planner instances are configuration (rebuilt from the
                // registry); their mutable state is restored from the
                // image inside `restore_bytes`.
                let planners: Option<Vec<Box<dyn MigrationPlanner>>> =
                    so.rebalance_planner.as_ref().map(|name| {
                        (0..self.policies.len())
                            .map(|_| {
                                crate::policies::planned::planner_from_name(
                                    name,
                                    &self.planner_config,
                                )
                                .unwrap_or_else(|| panic!("unknown rebalance planner '{name}'"))
                            })
                            .collect()
                    });
                ShardedCore::restore_bytes(&payload, self.policies, so.threads, planners)
                    .unwrap_or_else(|e| panic!("resume failed: {e}"))
            }
            None => ShardedCore::new(self.hosts, self.policies, so.seed, so.shards, so.threads),
        };
        core.set_integrity_every(self.options.integrity_every);
        core.set_on_corruption(self.options.on_corruption);
        let last_departure = self.vms.iter().map(|v| v.departure).max().unwrap_or(0);
        let horizon = if self.options.drain_cap_hours > 0 {
            last_arrival + self.options.drain_cap_hours * HOUR
        } else {
            last_departure.max(last_arrival)
        };
        core.reserve_for_trace(self.vms.len(), core.window_of(horizon) + 2);
        // Ops, queue and rebalance state all travel inside the snapshot
        // (per-shard schedule cursors, parked requests, move tallies);
        // re-wiring them on a resume would reset the restored state.
        if resume_hour.is_none() {
            if self.options.ops.enabled() {
                let mut ops = self.options.ops.clone();
                if ops.horizon_hours == 0 {
                    ops.horizon_hours = core.window_of(horizon) + 2;
                }
                // Global schedule over the *unsplit* fleet: identical
                // faults at every shard count.
                core.set_fault_schedule(FaultInjector::from_config(&ops, self.hosts));
            }
            if self.options.queue.enabled() {
                core.set_admission_queue(self.options.queue);
            }
            if so.rebalance_every > 0 {
                core.set_rebalance(so.rebalance_every, so.budget);
                if let Some(name) = &so.rebalance_planner {
                    let known = core.set_rebalance_planner(name, &self.planner_config);
                    assert!(known, "unknown rebalance planner '{name}'");
                }
            }
        }
        let mut checkpoint = self.options.effective_checkpoint_dir().map(|dir| {
            Checkpointer::new(
                dir,
                self.options.checkpoint_every_hours,
                SnapshotKind::Sharded,
                resume_hour,
            )
            .unwrap_or_else(|e| panic!("cannot open checkpoint directory {}: {e}", dir.display()))
        });
        let mut next_vm = match resume_hour {
            Some(_) => self.vms.partition_point(|v| v.arrival <= core.hour() * core.interval()),
            None => 0,
        };
        loop {
            let t_end = core.interval_end();
            let batch_start = next_vm;
            while next_vm < self.vms.len() && self.vms[next_vm].arrival <= t_end {
                next_vm += 1;
            }
            core.step_buffered(&self.vms[batch_start..next_vm]);
            if let Some(cp) = checkpoint.as_mut() {
                let rec = core.interval_record(core.hour() - 1);
                cp.interval_closed(&rec, || core.snapshot_bytes());
            }

            let drained = next_vm >= self.vms.len() && core.pending_departures() == 0;
            let capped = self.options.drain_cap_hours > 0
                && core.hour() * HOUR > last_arrival + self.options.drain_cap_hours * HOUR;
            if drained || capped {
                break;
            }
        }
        core.into_result(t_start.elapsed().as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::vm::HOUR;
    use crate::policies::first_fit::FirstFit;
    use crate::sim::Simulation;

    fn fleet(hosts: u32) -> Vec<Host> {
        (0..hosts).map(|i| Host::new(i, 64, 256, 4)).collect()
    }

    fn trace(n: u64) -> Vec<VmSpec> {
        use crate::mig::Profile;
        (0..n)
            .map(|i| VmSpec {
                id: i + 1,
                profile: match i % 3 {
                    0 => Profile::P1g5gb,
                    1 => Profile::P3g20gb,
                    _ => Profile::P7g40gb,
                },
                cpus: 2,
                ram_gb: 8,
                arrival: (i / 4) * HOUR + 60,
                departure: (i / 4 + 3 + i % 5) * HOUR + 60,
                weight: 1.0,
            })
            .collect()
    }

    fn policies(n: usize) -> Vec<Box<dyn Policy>> {
        (0..n).map(|_| Box::new(FirstFit::new()) as Box<dyn Policy>).collect()
    }

    #[test]
    fn single_shard_matches_unsharded_engine() {
        let hosts = fleet(6);
        let vms = trace(60);
        let unsharded = {
            let mut sim =
                Simulation::new(DataCenter::new(hosts.clone()), Box::new(FirstFit::new()), &vms);
            sim.options.integrity_every = 4;
            sim.ctx = PolicyCtx::new(11);
            sim.run()
        };
        let mut sharded = ShardedSimulation::new(&hosts, policies(1), &vms);
        sharded.options.integrity_every = 4;
        sharded.shard_options.seed = 11;
        let sharded = sharded.run();
        assert_eq!(unsharded.samples, sharded.samples);
        assert_eq!(unsharded.requested, sharded.requested);
        assert_eq!(unsharded.accepted, sharded.accepted);
        assert_eq!(unsharded.rejections, sharded.rejections);
        assert_eq!(unsharded.per_profile, sharded.per_profile);
        assert_eq!(unsharded.migration_events, sharded.migration_events);
        assert_eq!(unsharded.availability, sharded.availability);
    }

    #[test]
    fn multi_shard_accounting_invariant_holds() {
        let hosts = fleet(8);
        let vms = trace(120);
        let mut sim = ShardedSimulation::new(&hosts, policies(4), &vms);
        sim.options.integrity_every = 2;
        sim.shard_options.shards = 4;
        sim.shard_options.threads = 2;
        let r = sim.run();
        assert_eq!(r.requested, 120);
        assert_eq!(r.rejections.iter().sum::<u64>(), r.requested - r.accepted);
        let (profile_req, profile_acc): (u64, u64) =
            r.per_profile.iter().fold((0, 0), |(a, b), (x, y)| (a + x, b + y));
        assert_eq!(profile_req, r.requested);
        assert_eq!(profile_acc, r.accepted);
    }

    #[test]
    fn retry_chain_places_on_other_shards() {
        // Shard 0 is one tiny host; shard 1 has room. VM ids even →
        // home shard 0 under `id % 2`; once shard 0 fills, the retry
        // chain must land the overflow on shard 1 instead of rejecting.
        let hosts = vec![Host::new(0, 4, 16, 1), Host::new(1, 64, 256, 4)];
        use crate::mig::Profile;
        let vms: Vec<VmSpec> = (0..6)
            .map(|i| VmSpec {
                id: 2 * i + 2, // all even → all homed on shard 0
                profile: Profile::P7g40gb,
                cpus: 2,
                ram_gb: 8,
                arrival: 60,
                departure: 50 * HOUR,
                weight: 1.0,
            })
            .collect();
        let mut sim = ShardedSimulation::new(&hosts, policies(2), &vms);
        sim.options.integrity_every = 1;
        sim.options.drain_cap_hours = 2;
        sim.shard_options.shards = 2;
        let r = sim.run();
        // Shard 0 fits one 7g GI (then CPUs run out anyway); shard 1's
        // four GPUs absorb four more via the retry chain.
        assert_eq!(r.requested, 6);
        assert_eq!(r.accepted, 5);
        assert_eq!(r.rejections.iter().sum::<u64>(), 1);
    }

    #[test]
    fn rebalance_consolidates_across_shards() {
        use crate::mig::Profile;
        // Two shards, one host each; two VMs homed one per shard. With
        // rebalancing on, the sole-tenant GI migrates onto the other
        // shard's active GPU, emptying its donor host.
        let hosts = vec![Host::new(0, 64, 256, 1), Host::new(1, 64, 256, 1)];
        let vms: Vec<VmSpec> = (0..2)
            .map(|i| VmSpec {
                id: i + 2, // ids 2 (shard 0), 3 (shard 1)
                profile: Profile::P1g5gb,
                cpus: 2,
                ram_gb: 8,
                arrival: 60,
                departure: 40 * HOUR,
                weight: 1.0,
            })
            .collect();
        let mut sim = ShardedSimulation::new(&hosts, policies(2), &vms);
        sim.options.integrity_every = 1;
        sim.options.drain_cap_hours = 3;
        sim.shard_options.shards = 2;
        sim.shard_options.rebalance_every = 1;
        let r = sim.run();
        assert_eq!(r.accepted, 2);
        let inter =
            r.migration_events.iter().filter(|e| e.kind == MigrationKind::Inter).count();
        assert_eq!(inter, 1, "one cross-shard consolidation move");
        // Post-move the cluster still satisfies integrity (checked per
        // interval via integrity_every=1) and both VMs stay resident
        // until departure.
        assert_eq!(r.interrupted, 0);
    }

    /// The rebalancer consults a registry planner when one is named:
    /// ilp-repair's `Migrate` nomination (the cheapest consolidation of
    /// the donor shard) is evacuated onto the other shard's active GPU,
    /// and the whole pass is deterministic across runs.
    #[test]
    fn planner_rebalance_evacuates_nominated_vms() {
        use crate::mig::Profile;
        use crate::policies::PolicyConfig;
        // Hosts 0–1 form shard 0, hosts 2–3 shard 1 (one GPU each).
        // Seven 1g GIs fill host 0's GPU so the eighth (vm 16) lands on
        // host 1; five early departures then leave host 0 holding two
        // GIs and host 1 a sole tenant. The donor-side ILP nominates
        // the single-move consolidation — vm 16 — and the router
        // evacuates it onto shard 1's already-active GPU instead.
        let hosts: Vec<Host> = (0..4).map(|i| Host::new(i, 64, 256, 1)).collect();
        let mut vms: Vec<VmSpec> = (1..=8u64)
            .map(|i| VmSpec {
                id: 2 * i, // even → homed on shard 0
                profile: Profile::P1g5gb,
                cpus: 2,
                ram_gb: 8,
                arrival: 60,
                departure: if (2..=6).contains(&i) { 2 * HOUR + 60 } else { 40 * HOUR },
                weight: 1.0,
            })
            .collect();
        // One odd-id resident keeps shard 1's first GPU active.
        vms.push(VmSpec {
            id: 3,
            profile: Profile::P1g5gb,
            cpus: 2,
            ram_gb: 8,
            arrival: 60,
            departure: 40 * HOUR,
            weight: 1.0,
        });
        let run = || {
            let mut sim = ShardedSimulation::new(&hosts, policies(2), &vms);
            sim.options.integrity_every = 1;
            sim.options.drain_cap_hours = 4;
            sim.shard_options.shards = 2;
            sim.shard_options.rebalance_every = 1;
            sim.shard_options.rebalance_planner = Some("ilp-repair".to_string());
            sim.planner_config = PolicyConfig::new().ilp_period_hours(1);
            sim.run()
        };
        let r = run();
        assert_eq!(r.accepted, 9);
        let inter: Vec<_> =
            r.migration_events.iter().filter(|e| e.kind == MigrationKind::Inter).collect();
        assert_eq!(inter.len(), 1, "{:?}", r.migration_events);
        assert_eq!(inter[0].vm, 16, "the planner's nomination is the VM that moves");
        assert_eq!(inter[0].from.host, 1);
        assert_eq!(inter[0].to.host, 2, "evacuated onto shard 1's active GPU");
        assert_eq!(r.interrupted, 0);
        let again = run();
        assert_eq!(r.migration_events, again.migration_events);
        assert_eq!(r.samples, again.samples);
    }

    /// The sharded engine honours the same two recovery locks as the
    /// single-shard core: restore → re-snapshot is byte-identical, and
    /// a resumed run replays to the same merged result as the
    /// uninterrupted one — with queueing and cross-shard rebalancing on.
    #[test]
    fn sharded_snapshot_restore_round_trip() {
        let hosts = fleet(4);
        let vms = trace(24);
        let mut core = ShardedCore::new(&hosts, policies(2), 11, 2, 2);
        core.set_integrity_every(2);
        core.set_admission_queue(QueueConfig { capacity: 8, ttl_hours: 4, preemption: false });
        core.set_rebalance(1, MigrationBudget::unlimited());
        let mut next = 0usize;
        for _ in 0..3 {
            let t_end = core.interval_end();
            let start = next;
            while next < vms.len() && vms[next].arrival <= t_end {
                next += 1;
            }
            core.step_buffered(&vms[start..next]);
        }
        let snap = core.snapshot_bytes();
        let mut twin = ShardedCore::restore_bytes(&snap, policies(2), 2, None).unwrap();
        assert_eq!(twin.snapshot_bytes(), snap, "restore must be byte-exact");
        assert_eq!(twin.hour(), core.hour());
        // Rebalance period, budget and integrity cadence all travel in
        // the image — the twin needs no reconfiguration.
        loop {
            let t_end = core.interval_end();
            let start = next;
            while next < vms.len() && vms[next].arrival <= t_end {
                next += 1;
            }
            core.step_buffered(&vms[start..next]);
            twin.step_buffered(&vms[start..next]);
            assert_eq!(core.decisions(), twin.decisions(), "post-restore decisions diverged");
            if next >= vms.len() && core.pending_departures() == 0 {
                break;
            }
        }
        let ra = core.into_result(0.0);
        let rb = twin.into_result(5.0);
        assert!(ra.same_outcome(&rb), "resumed sharded run must match uninterrupted run");
    }
}
