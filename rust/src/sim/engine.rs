//! The discrete-event simulation loop (§6's online stochastic process).
//!
//! Time advances hour by hour (the paper's discrete intervals); the
//! actual per-interval mechanics — departures before arrivals, batch
//! placement, maintenance tick, metric sample — live in the shared
//! [`EventCore`], which the online coordinator drives with the same
//! semantics. The simulator's job reduces to slicing the trace into
//! interval batches and deciding when the run is over (trace drained or
//! the drain cap reached).

use super::event_core::EventCore;
use super::metrics::SimResult;
use crate::cluster::vm::{VmSpec, HOUR};
use crate::cluster::DataCenter;
use crate::ops::{FaultInjector, OpsConfig, QueueConfig};
use crate::policies::{Policy, PolicyCtx};
use crate::recover::{Checkpointer, IntervalRecord, OnCorruption, SnapshotKind, SnapshotStore};
use std::path::PathBuf;

/// Engine knobs.
#[derive(Debug, Clone)]
pub struct SimulationOptions {
    /// Run integrity checks every N hours (0 = disabled). Expensive;
    /// enabled in tests.
    pub integrity_every: u64,
    /// Stop this many hours after the last arrival even if VMs remain
    /// (0 = run to last departure).
    pub drain_cap_hours: u64,
    /// Fault/maintenance model; all rates zero by default (the injector
    /// draws nothing, so the run is byte-identical to a pre-ops build).
    /// When the horizon is left at zero a schedule is drawn over the
    /// trace's own span.
    pub ops: OpsConfig,
    /// Admission retry queue; capacity zero by default (disabled —
    /// rejections stay terminal exactly as before).
    pub queue: QueueConfig,
    /// Persist a full engine snapshot every N closed intervals into
    /// `checkpoint_dir` (0 = snapshots off; the interval journal is
    /// still written whenever a checkpoint directory is set).
    pub checkpoint_every_hours: u64,
    /// Directory for crash-safe state: atomic `snap-*.grmu` images plus
    /// the per-interval journal (see [`crate::recover`]). `None`
    /// disables persistence entirely — the default run is byte-identical
    /// to a build without the recovery layer.
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume from the newest *valid* snapshot in this directory instead
    /// of starting fresh (torn snapshots fall back to the previous one).
    /// The trace and configuration must match the crashed run; every
    /// journaled interval the resumed run re-closes is cross-checked
    /// against the journal and a mismatch aborts loudly.
    pub resume_from: Option<PathBuf>,
    /// Reaction to a failed maintenance-tick integrity check
    /// (`--on-corruption`): abort (default, the historical panic),
    /// quarantine the offending host, or rebuild derived state in place.
    pub on_corruption: OnCorruption,
}

impl Default for SimulationOptions {
    fn default() -> Self {
        SimulationOptions {
            integrity_every: 0,
            drain_cap_hours: 0,
            ops: OpsConfig::default(),
            queue: QueueConfig::default(),
            checkpoint_every_hours: 0,
            checkpoint_dir: None,
            resume_from: None,
            on_corruption: OnCorruption::default(),
        }
    }
}

impl SimulationOptions {
    /// The checkpoint directory in effect: an explicit `checkpoint_dir`,
    /// or — when only `--resume` was given — the resume directory, so a
    /// resumed run keeps journaling and snapshotting where the crashed
    /// run left off.
    pub(crate) fn effective_checkpoint_dir(&self) -> Option<&PathBuf> {
        self.checkpoint_dir.as_ref().or(self.resume_from.as_ref())
    }

    /// Load the newest valid snapshot for a resume, verifying the image
    /// kind. `None` when `resume_from` is unset; panics (loudly, this is
    /// an operator error) when the directory holds no valid snapshot or
    /// one of the wrong engine shape.
    pub(crate) fn load_resume_image(&self, want: SnapshotKind) -> Option<(u64, Vec<u8>)> {
        let dir = self.resume_from.as_ref()?;
        let store = SnapshotStore::open(dir)
            .unwrap_or_else(|e| panic!("cannot open resume directory {}: {e}", dir.display()));
        let Some((hour, kind, payload)) = store.latest_valid() else {
            panic!("no valid snapshot to resume from in {}", dir.display());
        };
        assert!(
            kind == want,
            "snapshot in {} is a {kind:?} image but this run needs {want:?} \
             (shard configuration differs from the crashed run?)",
            dir.display()
        );
        Some((hour, payload))
    }
}

/// Cumulative counters of a run at one closed interval boundary — the
/// journal record shared by both engines.
pub(crate) trait IntervalCounters {
    fn interval_record(&self, closed_hour: u64) -> IntervalRecord;
}

impl IntervalCounters for EventCore {
    fn interval_record(&self, closed_hour: u64) -> IntervalRecord {
        IntervalRecord {
            hour: closed_hour,
            requested: self.requested(),
            accepted: self.accepted(),
            rejections: self.rejections(),
            migrations: self.migration_events().len() as u64,
            interrupted: self.interrupted(),
            queue_len: self.queue_len() as u64,
        }
    }
}

/// A configured simulation run.
pub struct Simulation<'a> {
    pub dc: DataCenter,
    pub policy: Box<dyn Policy>,
    pub vms: &'a [VmSpec],
    pub options: SimulationOptions,
    /// Per-run policy context (clock, RNG, scorer backend). Replace it
    /// to seed the RNG or score through the XLA artifact.
    pub ctx: PolicyCtx,
}

impl<'a> Simulation<'a> {
    pub fn new(dc: DataCenter, policy: Box<dyn Policy>, vms: &'a [VmSpec]) -> Simulation<'a> {
        Simulation {
            dc,
            policy,
            vms,
            options: SimulationOptions::default(),
            ctx: PolicyCtx::default(),
        }
    }

    /// Run to completion and collect metrics.
    pub fn run(self) -> SimResult {
        let t_start = std::time::Instant::now();
        let last_arrival = self.vms.last().map(|v| v.arrival).unwrap_or(0);
        // Resume path: the snapshot replaces the fresh data center,
        // context and run state wholesale; knobs that are configuration
        // rather than state (integrity cadence, corruption action) are
        // reapplied from this run's options below.
        let resume = self.options.load_resume_image(SnapshotKind::Core);
        let resume_hour = resume.as_ref().map(|(h, _)| *h);
        let mut core = match resume {
            Some((_, payload)) => EventCore::restore_bytes(&payload, self.policy)
                .unwrap_or_else(|e| panic!("resume failed: {e}")),
            None => EventCore::new(self.dc, self.policy, self.ctx),
        };
        core.set_integrity_every(self.options.integrity_every);
        core.set_on_corruption(self.options.on_corruption);
        // Pre-size the core's collections from the trace: the run spans
        // the arrivals plus either the drain cap or the latest departure.
        let last_departure = self.vms.iter().map(|v| v.departure).max().unwrap_or(0);
        let horizon = if self.options.drain_cap_hours > 0 {
            last_arrival + self.options.drain_cap_hours * HOUR
        } else {
            last_departure.max(last_arrival)
        };
        core.reserve_for_trace(self.vms.len(), core.window_of(horizon) + 2);
        // Ops and queue state travel inside the snapshot (schedule
        // cursor, parked requests); re-wiring them on a resume would
        // reset the restored state.
        if resume_hour.is_none() {
            if self.options.ops.enabled() {
                let mut ops = self.options.ops.clone();
                if ops.horizon_hours == 0 {
                    ops.horizon_hours = core.window_of(horizon) + 2;
                }
                core.set_fault_schedule(FaultInjector::from_config(&ops, core.dc.hosts()));
            }
            if self.options.queue.enabled() {
                core.set_admission_queue(self.options.queue);
            }
        }
        let mut checkpoint = self.options.effective_checkpoint_dir().map(|dir| {
            Checkpointer::new(
                dir,
                self.options.checkpoint_every_hours,
                SnapshotKind::Core,
                resume_hour,
            )
            .unwrap_or_else(|e| panic!("cannot open checkpoint directory {}: {e}", dir.display()))
        });
        // Fast-forward the trace cursor past everything the restored
        // clock already consumed: interval `h` takes arrivals up to and
        // including `(h+1)·interval`, so after `hour()` closed intervals
        // the frontier is `hour()·interval`. (A fresh run starts at 0 —
        // arrivals at t = 0 belong to interval 0, not to the frontier.)
        let mut next_vm = match resume_hour {
            Some(_) => self.vms.partition_point(|v| v.arrival <= core.hour() * core.interval()),
            None => 0,
        };
        loop {
            let t_end = core.interval_end();
            let batch_start = next_vm;
            while next_vm < self.vms.len() && self.vms[next_vm].arrival <= t_end {
                next_vm += 1;
            }
            // Buffered step: the simulator aggregates through the core's
            // accounting, so the per-interval decision Vec is never built.
            core.step_buffered(&self.vms[batch_start..next_vm]);
            if let Some(cp) = checkpoint.as_mut() {
                let rec = core.interval_record(core.hour() - 1);
                cp.interval_closed(&rec, || core.snapshot_bytes());
            }

            let drained = next_vm >= self.vms.len() && core.pending_departures() == 0;
            let capped = self.options.drain_cap_hours > 0
                && core.hour() * HOUR > last_arrival + self.options.drain_cap_hours * HOUR;
            if drained || capped {
                break;
            }
        }
        core.into_result(t_start.elapsed().as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Host, VmId};
    use crate::mig::Profile;
    use crate::policies::first_fit::FirstFit;
    use crate::policies::RejectReason;

    fn vm(id: VmId, profile: Profile, arrival_h: u64, dur_h: u64) -> VmSpec {
        VmSpec {
            id,
            profile,
            cpus: 2,
            ram_gb: 8,
            arrival: arrival_h * HOUR + 60,
            departure: (arrival_h + dur_h) * HOUR + 60,
            weight: 1.0,
        }
    }

    fn one_gpu_dc() -> DataCenter {
        DataCenter::new(vec![Host::new(0, 64, 256, 1)])
    }

    #[test]
    fn accepts_when_capacity_available() {
        let vms = vec![vm(1, Profile::P3g20gb, 0, 5), vm(2, Profile::P3g20gb, 0, 5)];
        let mut sim = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &vms);
        sim.options.integrity_every = 1;
        let res = sim.run();
        assert_eq!(res.accepted, 2);
        assert_eq!(res.requested, 2);
        assert!((res.overall_acceptance() - 1.0).abs() < 1e-12);
        assert_eq!(res.rejections.iter().sum::<u64>(), 0);
    }

    #[test]
    fn rejects_when_full_then_frees_on_departure() {
        // One 7g.40gb occupies the GPU for 2 h; another arrives during,
        // gets rejected; a third arrives after departure and is accepted.
        let vms = vec![
            vm(1, Profile::P7g40gb, 0, 2),
            vm(2, Profile::P7g40gb, 1, 2),
            vm(3, Profile::P7g40gb, 5, 2),
        ];
        let mut sim = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &vms);
        sim.options.integrity_every = 1;
        let res = sim.run();
        assert_eq!(res.accepted, 2);
        assert_eq!(res.requested, 3);
        let (req, acc) = res.per_profile[Profile::P7g40gb.dense()];
        assert_eq!((req, acc), (3, 2));
        // The mid-flight rejection was a fragmentation (no-GI-fit) case.
        assert_eq!(res.rejected(RejectReason::NoGpuFit), 1);
    }

    #[test]
    fn departures_before_arrivals_within_hour() {
        // VM 1 departs at hour 3; VM 2 arrives in the same hour — the
        // freed GPU must be reusable immediately.
        let vms = vec![vm(1, Profile::P7g40gb, 0, 3), vm(2, Profile::P7g40gb, 3, 1)];
        let res = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &vms).run();
        assert_eq!(res.accepted, 2);
    }

    #[test]
    fn samples_track_active_hardware() {
        let vms = vec![vm(1, Profile::P1g5gb, 0, 3)];
        let res = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &vms).run();
        // Host + 1 GPU both active while VM resident.
        assert!(res.samples[0].active_rate > 0.99);
        // After departure the cluster drains to zero.
        assert!(res.samples.last().unwrap().active_rate < 0.01);
    }

    #[test]
    fn cpu_exhaustion_rejects_with_reason() {
        // Host with only 3 CPUs: second VM (2 CPUs each) cannot fit.
        let dc = DataCenter::new(vec![Host::new(0, 3, 256, 1)]);
        let vms = vec![vm(1, Profile::P1g5gb, 0, 5), vm(2, Profile::P1g5gb, 0, 5)];
        let res = Simulation::new(dc, Box::new(FirstFit::new()), &vms).run();
        assert_eq!(res.accepted, 1);
        assert_eq!(res.rejected(RejectReason::CpuExhausted), 1);
    }

    #[test]
    fn empty_workload() {
        let res = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &[]).run();
        assert_eq!(res.requested, 0);
        assert_eq!(res.samples.len(), 1);
        // Empty-denominator convention: no request refused → 1.0.
        assert!((res.overall_acceptance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn drain_cap_stops_long_tails() {
        let vms = vec![vm(1, Profile::P1g5gb, 0, 10_000)];
        let mut sim = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &vms);
        sim.options.drain_cap_hours = 5;
        let res = sim.run();
        assert!(res.samples.len() < 20);
    }

    #[test]
    fn ops_options_wire_into_the_run() {
        use crate::ops::{OpsConfig, QueueConfig};
        // Aggressive MTBF on a single GPU: the run must complete, keep
        // the accounting invariant, and stay fully deterministic.
        let vms: Vec<VmSpec> = (0..20).map(|i| vm(i + 1, Profile::P1g5gb, i / 2, 3)).collect();
        let run = || {
            let mut sim = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &vms);
            sim.options.integrity_every = 1;
            sim.options.ops = OpsConfig { seed: 3, ..Default::default() }.with_gpu_mtbf(5.0);
            sim.options.queue = QueueConfig { capacity: 8, ttl_hours: 4, preemption: false };
            sim.run()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.rejections, b.rejections);
        assert_eq!(a.samples, b.samples);
        assert_eq!(a.interrupted, b.interrupted);
        assert!(a.availability <= 1.0);
        assert_eq!(a.rejections.iter().sum::<u64>(), a.requested - a.accepted);
    }

    #[test]
    fn disabled_ops_options_change_nothing() {
        let vms = vec![vm(1, Profile::P3g20gb, 0, 5), vm(2, Profile::P3g20gb, 1, 5)];
        let baseline = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &vms).run();
        let mut sim = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &vms);
        sim.options.ops = crate::ops::OpsConfig::default(); // all rates zero
        sim.options.queue = crate::ops::QueueConfig::default(); // capacity zero
        let r = sim.run();
        assert_eq!(baseline.samples, r.samples);
        assert_eq!(baseline.rejections, r.rejections);
        assert_eq!(r.availability, 1.0);
    }

    #[test]
    fn seeded_ctx_is_deterministic() {
        let vms = vec![vm(1, Profile::P2g10gb, 0, 5), vm(2, Profile::P2g10gb, 1, 5)];
        let run = |seed: u64| {
            let mut sim = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &vms);
            sim.ctx = PolicyCtx::new(seed);
            sim.run()
        };
        let (a, b) = (run(7), run(7));
        assert_eq!(a.accepted, b.accepted);
        assert_eq!(a.samples, b.samples);
    }
}
