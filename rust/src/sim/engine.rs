//! The discrete-event simulation loop (§6's online stochastic process).
//!
//! Time advances hour by hour (the paper's discrete intervals). Within an
//! hour the engine: (1) releases VMs whose departure time has passed,
//! (2) presents the hour's arrivals to the policy as one batch, (3) fires
//! the policy's maintenance tick (GRMU's consolidation interval is a
//! multiple of this), and (4) samples metrics. Departures inside an hour
//! are processed *before* that hour's arrivals — blocks freed during the
//! interval are available to the interval's requests, as in an online
//! system with immediate reclamation.

use super::metrics::{Sample, SimResult};
use crate::cluster::vm::{Time, VmSpec, HOUR};
use crate::cluster::DataCenter;
use crate::policies::Policy;
use std::collections::BinaryHeap;

/// Engine knobs.
#[derive(Debug, Clone)]
pub struct SimulationOptions {
    /// Metric sampling period (seconds). Default: hourly.
    pub sample_period: Time,
    /// Run integrity checks every N hours (0 = disabled). Expensive;
    /// enabled in tests.
    pub integrity_every: u64,
    /// Stop this many hours after the last arrival even if VMs remain
    /// (0 = run to last departure).
    pub drain_cap_hours: u64,
}

impl Default for SimulationOptions {
    fn default() -> Self {
        SimulationOptions { sample_period: HOUR, integrity_every: 0, drain_cap_hours: 0 }
    }
}

/// A configured simulation run.
pub struct Simulation<'a> {
    pub dc: DataCenter,
    pub policy: Box<dyn Policy>,
    pub vms: &'a [VmSpec],
    pub options: SimulationOptions,
}

impl<'a> Simulation<'a> {
    pub fn new(dc: DataCenter, policy: Box<dyn Policy>, vms: &'a [VmSpec]) -> Simulation<'a> {
        Simulation { dc, policy, vms, options: SimulationOptions::default() }
    }

    /// Run to completion and collect metrics.
    pub fn run(mut self) -> SimResult {
        let t_start = std::time::Instant::now();
        let mut samples = Vec::new();
        let mut requested = 0u64;
        let mut accepted = 0u64;
        let mut per_profile = [(0u64, 0u64); 6];

        // Departure min-heap of accepted VMs: (time, vm id).
        let mut departures: BinaryHeap<std::cmp::Reverse<(Time, u64)>> = BinaryHeap::new();

        let last_arrival = self.vms.last().map(|v| v.arrival).unwrap_or(0);
        let mut next_vm = 0usize;
        let mut hour = 0u64;

        loop {
            let t_end = (hour + 1) * HOUR;

            // (1) departures due in (hour*HOUR, t_end] — processed first.
            while let Some(&std::cmp::Reverse((t, vm))) = departures.peek() {
                if t > t_end {
                    break;
                }
                departures.pop();
                self.dc.remove(vm);
                self.policy.on_departure(&mut self.dc, vm);
            }

            // (2) arrivals due in this hour, as one batch.
            let batch_start = next_vm;
            while next_vm < self.vms.len() && self.vms[next_vm].arrival <= t_end {
                next_vm += 1;
            }
            let batch = &self.vms[batch_start..next_vm];
            if !batch.is_empty() {
                let decisions = self.policy.place_batch(&mut self.dc, batch, t_end);
                debug_assert_eq!(decisions.len(), batch.len());
                for (vm, ok) in batch.iter().zip(&decisions) {
                    requested += 1;
                    per_profile[vm.profile.index()].0 += 1;
                    if *ok {
                        accepted += 1;
                        per_profile[vm.profile.index()].1 += 1;
                        departures.push(std::cmp::Reverse((vm.departure.max(t_end + 1), vm.id)));
                    }
                }
            }

            // (3) maintenance tick.
            self.policy.on_tick(&mut self.dc, t_end);

            // (4) metric sample.
            samples.push(Sample {
                hour,
                active_rate: self.dc.active_hardware_rate(),
                acceptance_rate: if requested == 0 {
                    1.0
                } else {
                    accepted as f64 / requested as f64
                },
                resident: self.dc.resident_count(),
            });

            if self.options.integrity_every > 0 && hour % self.options.integrity_every == 0 {
                self.dc.check_integrity().expect("datacenter integrity");
            }

            hour += 1;
            let drained = next_vm >= self.vms.len() && departures.is_empty();
            let capped = self.options.drain_cap_hours > 0
                && hour * HOUR > last_arrival + self.options.drain_cap_hours * HOUR;
            if drained || capped {
                break;
            }
        }

        SimResult {
            policy: self.policy.name().to_string(),
            samples,
            requested,
            accepted,
            per_profile,
            intra_migrations: self.policy.intra_migrations(),
            inter_migrations: self.policy.inter_migrations(),
            wall_seconds: t_start.elapsed().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Host, VmId};
    use crate::mig::Profile;
    use crate::policies::first_fit::FirstFit;

    fn vm(id: VmId, profile: Profile, arrival_h: u64, dur_h: u64) -> VmSpec {
        VmSpec {
            id,
            profile,
            cpus: 2,
            ram_gb: 8,
            arrival: arrival_h * HOUR + 60,
            departure: (arrival_h + dur_h) * HOUR + 60,
            weight: 1.0,
        }
    }

    fn one_gpu_dc() -> DataCenter {
        DataCenter::new(vec![Host::new(0, 64, 256, 1)])
    }

    #[test]
    fn accepts_when_capacity_available() {
        let vms = vec![vm(1, Profile::P3g20gb, 0, 5), vm(2, Profile::P3g20gb, 0, 5)];
        let mut sim = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &vms);
        sim.options.integrity_every = 1;
        let res = sim.run();
        assert_eq!(res.accepted, 2);
        assert_eq!(res.requested, 2);
        assert!((res.overall_acceptance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_when_full_then_frees_on_departure() {
        // One 7g.40gb occupies the GPU for 2 h; another arrives during,
        // gets rejected; a third arrives after departure and is accepted.
        let vms = vec![
            vm(1, Profile::P7g40gb, 0, 2),
            vm(2, Profile::P7g40gb, 1, 2),
            vm(3, Profile::P7g40gb, 5, 2),
        ];
        let mut sim = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &vms);
        sim.options.integrity_every = 1;
        let res = sim.run();
        assert_eq!(res.accepted, 2);
        assert_eq!(res.requested, 3);
        let (req, acc) = res.per_profile[Profile::P7g40gb.index()];
        assert_eq!((req, acc), (3, 2));
    }

    #[test]
    fn departures_before_arrivals_within_hour() {
        // VM 1 departs at hour 3; VM 2 arrives in the same hour — the
        // freed GPU must be reusable immediately.
        let vms = vec![vm(1, Profile::P7g40gb, 0, 3), vm(2, Profile::P7g40gb, 3, 1)];
        let res = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &vms).run();
        assert_eq!(res.accepted, 2);
    }

    #[test]
    fn samples_track_active_hardware() {
        let vms = vec![vm(1, Profile::P1g5gb, 0, 3)];
        let res = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &vms).run();
        // Host + 1 GPU both active while VM resident.
        assert!(res.samples[0].active_rate > 0.99);
        // After departure the cluster drains to zero.
        assert!(res.samples.last().unwrap().active_rate < 0.01);
    }

    #[test]
    fn cpu_exhaustion_rejects() {
        // Host with only 3 CPUs: second VM (2 CPUs each) cannot fit.
        let dc = DataCenter::new(vec![Host::new(0, 3, 256, 1)]);
        let vms = vec![vm(1, Profile::P1g5gb, 0, 5), vm(2, Profile::P1g5gb, 0, 5)];
        let res = Simulation::new(dc, Box::new(FirstFit::new()), &vms).run();
        assert_eq!(res.accepted, 1);
    }

    #[test]
    fn empty_workload() {
        let res = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &[]).run();
        assert_eq!(res.requested, 0);
        assert_eq!(res.samples.len(), 1);
    }

    #[test]
    fn drain_cap_stops_long_tails() {
        let vms = vec![vm(1, Profile::P1g5gb, 0, 10_000)];
        let mut sim = Simulation::new(one_gpu_dc(), Box::new(FirstFit::new()), &vms);
        sim.options.drain_cap_hours = 5;
        let res = sim.run();
        assert!(res.samples.len() < 20);
    }
}
