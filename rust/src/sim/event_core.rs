//! The shared event core driving both the offline simulator and the
//! online coordinator.
//!
//! Before the decision-API redesign, `sim::engine` and
//! `coordinator::service` each carried their own departure heap, interval
//! batching, maintenance-tick and metric-sampling loop — and disagreed on
//! details (departure deadlines, empty-denominator conventions). The
//! [`EventCore`] owns that loop once:
//!
//! * a departure min-heap of accepted VMs, released *before* the
//!   interval's arrivals (blocks freed during an interval serve the
//!   interval's requests, as in an online system with immediate
//!   reclamation);
//! * interval-batched placement through the [`Policy`] trait's typed
//!   [`Decision`]s, with per-[`crate::policies::RejectReason`] accounting;
//! * the per-interval maintenance tick (GRMU's consolidation clock) and
//!   hourly metric sample;
//! * collection of the policy's [`MigrationEvent`] records.
//!
//! The simulator calls [`EventCore::step_buffered`] for every interval of
//! a trace; the coordinator calls
//! [`EventCore::run_until`]/[`EventCore::place_buffered`] as requests
//! arrive. Both end in the same [`SimResult`], which is what the
//! simulator-vs-coordinator equivalence test locks down.
//!
//! Since §Perf iteration 6 the steady-state loop is allocation-free and
//! scan-free: decisions land in the [`PolicyCtx`]'s reusable
//! [`crate::policies::DecisionBuffer`] (the `Vec`-returning
//! [`EventCore::step`]/[`EventCore::place`] remain as compat wrappers),
//! migrations drain via [`Policy::drain_migrations_into`] into a
//! pre-sized log, and the per-interval sample reads the data center's
//! O(1) activity counters instead of scanning the fleet.
//! [`EventCore::reserve_for_trace`] pre-sizes the departure heap, sample
//! vector and migration log from trace metadata.

use super::metrics::{acceptance_rate, Sample, SimResult};
use crate::cluster::vm::{Time, VmId, VmSpec, HOUR};
use crate::cluster::DataCenter;
use crate::mig::{NUM_MODELS, NUM_PROFILE_KEYS};
use crate::policies::{Decision, MigrationEvent, Policy, PolicyCtx, RejectCounts};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// The unified departure-heap / batch / tick / sample loop.
pub struct EventCore {
    pub dc: DataCenter,
    pub policy: Box<dyn Policy>,
    pub ctx: PolicyCtx,
    interval: Time,
    /// Run integrity checks every N intervals (0 = disabled). Expensive;
    /// enabled in tests.
    integrity_every: u64,
    /// Departure min-heap of accepted VMs: (time, vm id).
    departures: BinaryHeap<Reverse<(Time, VmId)>>,
    /// Index of the currently open (not yet closed) interval.
    hour: u64,
    samples: Vec<Sample>,
    requested: u64,
    accepted: u64,
    /// Per-profile `(requested, accepted)` by dense cross-model key.
    per_profile: [(u64, u64); NUM_PROFILE_KEYS],
    rejections: RejectCounts,
    migrations: Vec<MigrationEvent>,
    /// Cumulative block-weighted migration cost per
    /// [`crate::policies::MigrationKind`] (by `MigrationKind::index`),
    /// accumulated as events are absorbed so online readers (the
    /// coordinator's stats endpoint) get it in O(1).
    migration_cost: [u64; 2],
    /// Cumulative per-model `(active, total)` GPU-interval counts,
    /// accumulated at every sample (the per-model active-hardware
    /// breakdown of heterogeneous fleets).
    gpu_activity: [(u64, u64); NUM_MODELS],
}

impl EventCore {
    /// A core with hourly intervals (the paper's discrete clock).
    pub fn new(dc: DataCenter, policy: Box<dyn Policy>, ctx: PolicyCtx) -> EventCore {
        EventCore::with_interval(dc, policy, ctx, HOUR)
    }

    pub fn with_interval(
        dc: DataCenter,
        policy: Box<dyn Policy>,
        ctx: PolicyCtx,
        interval: Time,
    ) -> EventCore {
        EventCore {
            dc,
            policy,
            ctx,
            interval: interval.max(1),
            integrity_every: 0,
            departures: BinaryHeap::new(),
            hour: 0,
            samples: Vec::new(),
            requested: 0,
            accepted: 0,
            per_profile: [(0, 0); NUM_PROFILE_KEYS],
            rejections: [0; 4],
            migrations: Vec::new(),
            migration_cost: [0; 2],
            gpu_activity: [(0, 0); NUM_MODELS],
        }
    }

    pub fn set_integrity_every(&mut self, every: u64) {
        self.integrity_every = every;
    }

    /// Pre-size the run's collections from trace metadata so the
    /// steady-state loop never grows them: `requests` bounds the
    /// departure heap (every entry is an accepted, still-resident VM) and
    /// `intervals` bounds the sample vector. The migration log gets a
    /// small share of `requests` (§8.3.3 measures migrations ≈ 1% of
    /// accepted VMs); a heavier migration load merely amortizes growth.
    pub fn reserve_for_trace(&mut self, requests: usize, intervals: u64) {
        self.departures.reserve(requests);
        self.samples.reserve(intervals as usize);
        self.migrations.reserve(requests / 32 + 1);
    }

    pub fn interval(&self) -> Time {
        self.interval
    }

    /// Index of the open interval.
    pub fn hour(&self) -> u64 {
        self.hour
    }

    /// End time of the open interval.
    pub fn interval_end(&self) -> Time {
        (self.hour + 1) * self.interval
    }

    /// The interval that owns an arrival at `t`: intervals cover
    /// `(w·interval, (w+1)·interval]`, with `t = 0` in interval 0.
    pub fn window_of(&self, t: Time) -> u64 {
        if t == 0 {
            0
        } else {
            (t - 1) / self.interval
        }
    }

    pub fn pending_departures(&self) -> usize {
        self.departures.len()
    }

    pub fn requested(&self) -> u64 {
        self.requested
    }

    pub fn accepted(&self) -> u64 {
        self.accepted
    }

    /// Rejections so far, indexed by [`crate::policies::RejectReason::index`].
    pub fn rejections(&self) -> RejectCounts {
        self.rejections
    }

    /// Migrations recorded so far.
    pub fn migration_events(&self) -> &[MigrationEvent] {
        &self.migrations
    }

    /// Cumulative block-weighted migration cost so far, indexed by
    /// [`crate::policies::MigrationKind::index`] (`[intra, inter]`).
    pub fn migration_cost(&self) -> [u64; 2] {
        self.migration_cost
    }

    fn absorb_migrations(&mut self) {
        let start = self.migrations.len();
        self.policy.drain_migrations_into(&mut self.migrations);
        for ev in &self.migrations[start..] {
            self.migration_cost[ev.kind.index()] += ev.cost();
        }
    }

    /// Release departures due by `t` (inclusive), oldest first.
    pub fn release_due(&mut self, t: Time) {
        while let Some(&Reverse((due, vm))) = self.departures.peek() {
            if due > t {
                break;
            }
            self.departures.pop();
            self.dc.remove(vm);
            self.policy.on_departure(&mut self.dc, vm, &mut self.ctx);
        }
    }

    /// Present `batch` to the policy at the end of the open interval and
    /// account the decisions. A VM placed in interval `w` departs no
    /// earlier than the start of interval `w+1`.
    ///
    /// Compat wrapper around [`EventCore::place_buffered`]; callers that
    /// do not need an owned `Vec` should use the buffered variant.
    pub fn place(&mut self, batch: &[VmSpec]) -> Vec<Decision> {
        self.place_buffered(batch);
        self.ctx.decisions.to_vec()
    }

    /// Allocation-free [`EventCore::place`]: the decisions land in the
    /// context's [`crate::policies::DecisionBuffer`] (read them via
    /// [`EventCore::decisions`]) and stay valid until the next batch.
    pub fn place_buffered(&mut self, batch: &[VmSpec]) {
        if batch.is_empty() {
            self.ctx.decisions.begin(0);
            return;
        }
        let t_end = self.interval_end();
        self.ctx.now = t_end;
        // Reset the buffer here too (idempotent with the policies' own
        // `begin`): a policy that forgets it must not leave the previous
        // batch's decisions to be zipped against this batch's VMs.
        self.ctx.decisions.begin(batch.len());
        self.policy.place_batch_into(&mut self.dc, batch, &mut self.ctx);
        debug_assert_eq!(self.ctx.decisions.len(), batch.len());
        for (vm, d) in batch.iter().zip(self.ctx.decisions.as_slice()) {
            self.requested += 1;
            self.per_profile[vm.profile.dense()].0 += 1;
            match d {
                Decision::Placed { .. } => {
                    self.accepted += 1;
                    self.per_profile[vm.profile.dense()].1 += 1;
                    self.departures.push(Reverse((vm.departure.max(t_end + 1), vm.id)));
                }
                Decision::Rejected(reason) => self.rejections[reason.index()] += 1,
            }
        }
        self.absorb_migrations();
    }

    /// Decisions of the latest batch, in request order (empty before the
    /// first batch and after an empty one).
    pub fn decisions(&self) -> &[Decision] {
        self.ctx.decisions.as_slice()
    }

    /// Close the open interval: fire the maintenance tick, take the
    /// metric sample, advance the clock. The sample reads the data
    /// center's O(1) activity counters — no per-interval fleet scan.
    pub fn close_interval(&mut self) {
        let t_end = self.interval_end();
        self.ctx.now = t_end;
        self.policy.on_tick(&mut self.dc, &mut self.ctx);
        self.absorb_migrations();
        for (acc, (active, total)) in
            self.gpu_activity.iter_mut().zip(self.dc.active_gpus_by_model())
        {
            acc.0 += active as u64;
            acc.1 += total as u64;
        }
        self.samples.push(Sample {
            hour: self.hour,
            active_rate: self.dc.active_hardware_rate(),
            acceptance_rate: acceptance_rate(self.accepted, self.requested),
            resident: self.dc.resident_count(),
        });
        if self.integrity_every > 0 && self.hour % self.integrity_every == 0 {
            self.dc.check_integrity().expect("datacenter integrity");
        }
        self.hour += 1;
    }

    /// One full interval: departures, arrivals, tick, sample. Compat
    /// wrapper around [`EventCore::step_buffered`].
    pub fn step(&mut self, batch: &[VmSpec]) -> Vec<Decision> {
        self.step_buffered(batch);
        self.ctx.decisions.to_vec()
    }

    /// Allocation-free [`EventCore::step`]: returns the batch's
    /// decisions as a slice into the context's decision buffer.
    pub fn step_buffered(&mut self, batch: &[VmSpec]) -> &[Decision] {
        self.release_due(self.interval_end());
        self.place_buffered(batch);
        self.close_interval();
        self.ctx.decisions.as_slice()
    }

    /// Run empty intervals until `window` is the open interval. Lets the
    /// coordinator catch up on request-free intervals exactly as the
    /// simulator would have (departures released per interval, ticks at
    /// every boundary).
    pub fn run_until(&mut self, window: u64) {
        while self.hour < window {
            self.step_buffered(&[]);
        }
    }

    /// Finish: package everything into the shared result type.
    pub fn into_result(self, wall_seconds: f64) -> SimResult {
        SimResult {
            policy: self.policy.name().to_string(),
            samples: self.samples,
            requested: self.requested,
            accepted: self.accepted,
            per_profile: self.per_profile,
            rejections: self.rejections,
            migration_events: self.migrations,
            gpus_by_model: self.dc.gpus_by_model(),
            gpu_activity: self.gpu_activity,
            wall_seconds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;
    use crate::mig::Profile;
    use crate::policies::first_fit::FirstFit;
    use crate::policies::RejectReason;

    fn core(gpus: usize) -> EventCore {
        EventCore::new(
            DataCenter::new(vec![Host::new(0, 64, 256, gpus)]),
            Box::new(FirstFit::new()),
            PolicyCtx::default(),
        )
    }

    fn vm(id: VmId, profile: Profile, arrival: Time, departure: Time) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival, departure, weight: 1.0 }
    }

    #[test]
    fn windows_partition_the_clock() {
        let c = core(1);
        assert_eq!(c.window_of(0), 0);
        assert_eq!(c.window_of(1), 0);
        assert_eq!(c.window_of(HOUR), 0);
        assert_eq!(c.window_of(HOUR + 1), 1);
        assert_eq!(c.window_of(2 * HOUR), 1);
    }

    #[test]
    fn departures_released_before_next_window_arrivals() {
        let mut c = core(1);
        // Placed in interval 0, departs at 100 → deadline clamps to the
        // start of interval 1.
        c.step(&[vm(1, Profile::P7g40gb, 10, 100)]);
        assert_eq!(c.pending_departures(), 1);
        let d = c.step(&[vm(2, Profile::P7g40gb, HOUR + 5, 9 * HOUR)]);
        assert!(d[0].is_placed(), "freed GPU must be reusable");
    }

    #[test]
    fn empty_steps_sample_and_advance() {
        let mut c = core(1);
        c.run_until(3);
        assert_eq!(c.hour(), 3);
        let r = c.into_result(0.0);
        assert_eq!(r.samples.len(), 3);
        assert_eq!(r.requested, 0);
        // Empty-denominator convention: vacuous acceptance is 1.0.
        assert!((r.samples[0].acceptance_rate - 1.0).abs() < 1e-12);
    }

    #[test]
    fn buffered_and_vec_paths_agree() {
        let mut c = core(2);
        c.reserve_for_trace(4, 4);
        let d = c.step(&[vm(1, Profile::P3g20gb, 10, 100)]);
        // The compat Vec is a copy of the context's decision buffer.
        assert_eq!(d.as_slice(), c.decisions());
        let d2 = c.step_buffered(&[vm(2, Profile::P3g20gb, HOUR + 5, 9 * HOUR)]).to_vec();
        assert!(d2[0].is_placed());
        assert_eq!(c.decisions(), d2.as_slice());
        // An empty batch clears the buffer (no stale decisions).
        c.step_buffered(&[]);
        assert!(c.decisions().is_empty());
    }

    #[test]
    fn rejection_reasons_accumulate() {
        let mut c = core(1);
        c.step(&[vm(1, Profile::P7g40gb, 0, 99 * HOUR), vm(2, Profile::P1g5gb, 0, 99 * HOUR)]);
        let rej = c.rejections();
        assert_eq!(rej[RejectReason::NoGpuFit.index()], 1);
        assert_eq!(rej.iter().sum::<u64>(), 1);
    }
}
