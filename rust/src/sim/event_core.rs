//! The shared event core driving both the offline simulator and the
//! online coordinator.
//!
//! Before the decision-API redesign, `sim::engine` and
//! `coordinator::service` each carried their own departure heap, interval
//! batching, maintenance-tick and metric-sampling loop — and disagreed on
//! details (departure deadlines, empty-denominator conventions). The
//! [`EventCore`] owns that loop once:
//!
//! * a departure min-heap of accepted VMs, released *before* the
//!   interval's arrivals (blocks freed during an interval serve the
//!   interval's requests, as in an online system with immediate
//!   reclamation);
//! * interval-batched placement through the [`Policy`] trait's typed
//!   [`Decision`]s, with per-[`crate::policies::RejectReason`] accounting;
//! * the per-interval maintenance tick (GRMU's consolidation clock) and
//!   hourly metric sample;
//! * collection of the policy's [`MigrationEvent`] records;
//! * replay of the [`crate::ops`] fault/repair/drain schedule (at the
//!   end of every `release_due`, after the interval's departures) with
//!   eviction, all-or-nothing drain evacuation and availability
//!   accounting;
//! * the admission queue's once-per-interval expiry + FIFO retry pass
//!   (before the interval's fresh batch) and, under preemption,
//!   high-tier displacement of low-tier residents. Disabled ops leave
//!   every decision stream byte-identical to the pre-ops core.
//!
//! The simulator calls [`EventCore::step_buffered`] for every interval of
//! a trace; the coordinator calls
//! [`EventCore::run_until`]/[`EventCore::place_buffered`] as requests
//! arrive. Both end in the same [`SimResult`], which is what the
//! simulator-vs-coordinator equivalence test locks down.
//!
//! Since §Perf iteration 6 the steady-state loop is allocation-free and
//! scan-free: decisions land in the [`PolicyCtx`]'s reusable
//! [`crate::policies::DecisionBuffer`] (the `Vec`-returning
//! [`EventCore::step`]/[`EventCore::place`] remain as compat wrappers),
//! migrations drain via [`Policy::drain_migrations_into`] into a
//! pre-sized log, and the per-interval sample reads the data center's
//! O(1) activity counters instead of scanning the fleet.
//! [`EventCore::reserve_for_trace`] pre-sizes the departure heap, sample
//! vector and migration log from trace metadata.

use super::metrics::{acceptance_rate, Sample, SimResult};
use crate::cluster::vm::{Time, VmId, VmSpec, HOUR};
use crate::cluster::{DataCenter, GpuRef, HealthState, IntegrityReport};
use crate::mig::{mock_assign, Instance, Placement, NUM_MODELS, NUM_PROFILE_KEYS};
use crate::ops::{
    plan_evacuation, tier_of, AdmissionQueue, FaultInjector, OpsEvent, QueueConfig, QueuedRequest,
    Tier, STATE_REPAIR_NO_HOST,
};
use crate::policies::{Decision, MigrationEvent, Policy, PolicyCtx, RejectCounts, RejectReason};
use crate::recover::OnCorruption;
use crate::util::codec::{Dec, Enc};
use crate::util::rng::Rng;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// The unified departure-heap / batch / tick / sample loop.
pub struct EventCore {
    pub dc: DataCenter,
    pub policy: Box<dyn Policy>,
    pub ctx: PolicyCtx,
    interval: Time,
    /// Run integrity checks every N intervals (0 = disabled). Expensive;
    /// enabled in tests.
    integrity_every: u64,
    /// Departure min-heap of accepted VMs: (time, vm id).
    departures: BinaryHeap<Reverse<(Time, VmId)>>,
    /// Index of the currently open (not yet closed) interval.
    hour: u64,
    samples: Vec<Sample>,
    requested: u64,
    accepted: u64,
    /// Per-profile `(requested, accepted)` by dense cross-model key.
    per_profile: [(u64, u64); NUM_PROFILE_KEYS],
    rejections: RejectCounts,
    migrations: Vec<MigrationEvent>,
    /// Cumulative block-weighted migration cost per
    /// [`crate::policies::MigrationKind`] (by `MigrationKind::index`),
    /// accumulated as events are absorbed so online readers (the
    /// coordinator's stats endpoint) get it in O(1).
    migration_cost: [u64; 2],
    /// Cumulative per-model `(active, total)` GPU-interval counts,
    /// accumulated at every sample (the per-model active-hardware
    /// breakdown of heterogeneous fleets).
    gpu_activity: [(u64, u64); NUM_MODELS],
    /// Scheduled operational events (faults/repairs/drains), replayed at
    /// the end of every [`EventCore::release_due`]. Empty by default.
    injector: FaultInjector,
    /// Bounded retry queue for retryable rejections; disabled by default.
    queue: AdmissionQueue,
    /// Interval already queue-processed (guards the coordinator's
    /// several `place_buffered` calls per window — the simulator
    /// processes each interval exactly once).
    queue_done_hour: u64,
    /// Reusable FIFO retry-pass buffer.
    retry_scratch: Vec<QueuedRequest>,
    /// Stale departure-heap entries per VM: evictions/preemptions leave
    /// their heap entry behind; `release_due` skips that many pops.
    revoked: HashMap<VmId, u32>,
    /// Specs of resident VMs — maintained only under preemption, which
    /// must know victims' tiers and re-enqueue their full spec.
    resident_specs: HashMap<VmId, VmSpec>,
    /// VMs evicted by hardware failures (terminal; not a rejection).
    interrupted: u64,
    /// VMs preempted back into the queue by high-tier arrivals.
    preempted: u64,
    /// Queueing delay (seconds) of each request served from the queue.
    queue_delays: Vec<u64>,
    /// Optimality-gap samples drained from the policy (only a
    /// gap-metered policy produces any).
    gap_samples: Vec<f64>,
    /// GPU-interval availability accumulator: (schedulable, total).
    gpu_intervals_available: u64,
    gpu_intervals_total: u64,
    /// What a failed integrity check at a maintenance tick does.
    /// [`OnCorruption::Abort`] (the default) keeps the historical panic.
    on_corruption: OnCorruption,
    /// [`OpsEvent::StateRepair`] log: every graceful-degradation repair
    /// performed, with its interval-end timestamp.
    repairs: Vec<(Time, OpsEvent)>,
}

impl EventCore {
    /// A core with hourly intervals (the paper's discrete clock).
    pub fn new(dc: DataCenter, policy: Box<dyn Policy>, ctx: PolicyCtx) -> EventCore {
        EventCore::with_interval(dc, policy, ctx, HOUR)
    }

    pub fn with_interval(
        dc: DataCenter,
        policy: Box<dyn Policy>,
        ctx: PolicyCtx,
        interval: Time,
    ) -> EventCore {
        EventCore {
            dc,
            policy,
            ctx,
            interval: interval.max(1),
            integrity_every: 0,
            departures: BinaryHeap::new(),
            hour: 0,
            samples: Vec::new(),
            requested: 0,
            accepted: 0,
            per_profile: [(0, 0); NUM_PROFILE_KEYS],
            rejections: [0; 6],
            migrations: Vec::new(),
            migration_cost: [0; 2],
            gpu_activity: [(0, 0); NUM_MODELS],
            injector: FaultInjector::default(),
            queue: AdmissionQueue::default(),
            queue_done_hour: u64::MAX,
            retry_scratch: Vec::new(),
            revoked: HashMap::new(),
            resident_specs: HashMap::new(),
            interrupted: 0,
            preempted: 0,
            queue_delays: Vec::new(),
            gap_samples: Vec::new(),
            gpu_intervals_available: 0,
            gpu_intervals_total: 0,
            on_corruption: OnCorruption::default(),
            repairs: Vec::new(),
        }
    }

    /// Install a fault/maintenance schedule (see [`crate::ops::fault`]).
    /// Call before the run starts; the default injector is empty and the
    /// replay is a no-op.
    pub fn set_fault_schedule(&mut self, injector: FaultInjector) {
        self.injector = injector;
    }

    /// Configure admission queueing (see [`crate::ops::queue`]). Call
    /// before the run starts; the default (`capacity == 0`) keeps every
    /// rejection terminal and the decision stream byte-identical to the
    /// pre-queue behaviour.
    pub fn set_admission_queue(&mut self, cfg: QueueConfig) {
        self.queue = AdmissionQueue::new(cfg);
    }

    pub fn set_integrity_every(&mut self, every: u64) {
        self.integrity_every = every;
    }

    /// Choose what a failed integrity check does (see
    /// [`crate::recover::OnCorruption`]). The default `Abort` keeps the
    /// historical panic; `Quarantine`/`Rebuild` degrade gracefully and
    /// log an [`OpsEvent::StateRepair`].
    pub fn set_on_corruption(&mut self, action: OnCorruption) {
        self.on_corruption = action;
    }

    /// Graceful-degradation repairs performed so far (empty unless
    /// corruption was detected under `Quarantine`/`Rebuild`).
    pub fn state_repairs(&self) -> &[(Time, OpsEvent)] {
        &self.repairs
    }

    /// Pre-size the run's collections from trace metadata so the
    /// steady-state loop never grows them: `requests` bounds the
    /// departure heap (every entry is an accepted, still-resident VM) and
    /// `intervals` bounds the sample vector. The migration log gets a
    /// small share of `requests` (§8.3.3 measures migrations ≈ 1% of
    /// accepted VMs); a heavier migration load merely amortizes growth.
    pub fn reserve_for_trace(&mut self, requests: usize, intervals: u64) {
        self.departures.reserve(requests);
        self.samples.reserve(intervals as usize);
        self.migrations.reserve(requests / 32 + 1);
    }

    pub fn interval(&self) -> Time {
        self.interval
    }

    /// Index of the open interval.
    pub fn hour(&self) -> u64 {
        self.hour
    }

    /// End time of the open interval.
    pub fn interval_end(&self) -> Time {
        (self.hour + 1) * self.interval
    }

    /// The interval that owns an arrival at `t`: intervals cover
    /// `(w·interval, (w+1)·interval]`, with `t = 0` in interval 0.
    pub fn window_of(&self, t: Time) -> u64 {
        if t == 0 {
            0
        } else {
            (t - 1) / self.interval
        }
    }

    pub fn pending_departures(&self) -> usize {
        self.departures.len()
    }

    pub fn requested(&self) -> u64 {
        self.requested
    }

    pub fn accepted(&self) -> u64 {
        self.accepted
    }

    /// Rejections so far, indexed by [`crate::policies::RejectReason::index`].
    pub fn rejections(&self) -> RejectCounts {
        self.rejections
    }

    /// Migrations recorded so far.
    pub fn migration_events(&self) -> &[MigrationEvent] {
        &self.migrations
    }

    /// Cumulative block-weighted migration cost so far, indexed by
    /// [`crate::policies::MigrationKind::index`] (`[intra, inter]`).
    pub fn migration_cost(&self) -> [u64; 2] {
        self.migration_cost
    }

    fn absorb_migrations(&mut self) {
        let start = self.migrations.len();
        self.policy.drain_migrations_into(&mut self.migrations);
        for ev in &self.migrations[start..] {
            self.migration_cost[ev.kind.index()] += ev.cost();
        }
        // Piggy-back the gap drain on the same cadence: a no-op for
        // every policy except a gap-metered wrapper.
        self.policy.drain_gap_samples_into(&mut self.gap_samples);
    }

    /// Release departures due by `t` (inclusive), oldest first, then
    /// apply the operational events due by `t` (departures first:
    /// capacity freed during the interval is not pointlessly evicted).
    pub fn release_due(&mut self, t: Time) {
        while let Some(&Reverse((due, vm))) = self.departures.peek() {
            if due > t {
                break;
            }
            self.departures.pop();
            if !self.revoked.is_empty() {
                // An evicted/preempted VM left this entry behind — skip
                // it (a re-placed VM pushed a fresh entry of its own).
                if let Some(n) = self.revoked.get_mut(&vm) {
                    *n -= 1;
                    if *n == 0 {
                        self.revoked.remove(&vm);
                    }
                    continue;
                }
            }
            self.dc.remove(vm);
            self.policy.on_departure(&mut self.dc, vm, &mut self.ctx);
            if !self.resident_specs.is_empty() {
                self.resident_specs.remove(&vm);
            }
        }
        self.apply_ops(t);
    }

    /// Replay scheduled fault/repair/drain events with timestamps ≤ `t`.
    fn apply_ops(&mut self, t: Time) {
        while let Some((due, ev)) = self.injector.pop_due(t) {
            match ev {
                OpsEvent::GpuFail { gpu, until } => {
                    // Evict residents while the index still covers the
                    // device, then take it offline.
                    for vm in self.dc.vms_on_gpu(gpu) {
                        self.evict(vm);
                    }
                    self.dc.set_gpu_health(gpu, HealthState::Failed { until });
                    let _ = self.injector.record_failure(gpu);
                }
                OpsEvent::GpuRepair { gpu } => {
                    let restored = if self.injector.is_banned(gpu) {
                        HealthState::Banned // repeat offender: blocklisted
                    } else {
                        HealthState::Healthy
                    };
                    self.dc.set_gpu_health(gpu, restored);
                }
                OpsEvent::HostFail { host, until } => {
                    for vm in self.dc.vms_on_host(host) {
                        self.evict(vm);
                    }
                    // Correlated (blast-radius) failures can overlap: a
                    // second hit while already down extends the outage,
                    // never shortens it.
                    let until = match self.dc.host_health(host) {
                        HealthState::Failed { until: prev } => prev.max(until),
                        _ => until,
                    };
                    self.dc.set_host_health(host, HealthState::Failed { until });
                }
                OpsEvent::HostRepair { host } => {
                    // A drain that began before the failure stays void;
                    // a repair belonging to a shorter, overlapped outage
                    // must not resurrect a host another failure still
                    // holds down (`until` past this repair's timestamp).
                    if let HealthState::Failed { until } = self.dc.host_health(host) {
                        if until <= due {
                            self.dc.set_host_health(host, HealthState::Healthy);
                        }
                    }
                }
                OpsEvent::DrainStart { host, .. } => {
                    // Only a healthy host can enter maintenance.
                    if self.dc.host_health(host) != HealthState::Healthy {
                        continue;
                    }
                    self.dc.set_host_health(host, HealthState::Draining);
                    // Best-effort, all-or-nothing evacuation through the
                    // transactional planner layer; a refused plan leaves
                    // residents in place (they keep running — draining
                    // allows residency, just no new placements).
                    if let Some(plan) = plan_evacuation(&self.dc, host) {
                        if !plan.is_empty() && self.dc.apply_plan(&plan).is_ok() {
                            let start = self.migrations.len();
                            plan.push_events_into(&mut self.migrations);
                            for ev in &self.migrations[start..] {
                                self.migration_cost[ev.kind.index()] += ev.cost();
                            }
                        }
                    }
                }
                OpsEvent::DrainDone { host } => {
                    // A failure during the drain wins; only a still-
                    // draining host returns to service.
                    if self.dc.host_health(host) == HealthState::Draining {
                        self.dc.set_host_health(host, HealthState::Healthy);
                    }
                }
                OpsEvent::StateRepair { .. } => {
                    // Log-only event: emitted by `repair_state`, never
                    // part of a generated schedule.
                }
            }
        }
    }

    /// Evict one VM for a hardware failure: terminal (no re-queue), the
    /// VM counts as interrupted and its departure-heap entry is revoked.
    fn evict(&mut self, vm: VmId) {
        self.dc.remove(vm);
        self.policy.on_departure(&mut self.dc, vm, &mut self.ctx);
        *self.revoked.entry(vm).or_insert(0) += 1;
        self.interrupted += 1;
        if !self.resident_specs.is_empty() {
            self.resident_specs.remove(&vm);
        }
    }

    /// Present `batch` to the policy at the end of the open interval and
    /// account the decisions. A VM placed in interval `w` departs no
    /// earlier than the start of interval `w+1`.
    ///
    /// Compat wrapper around [`EventCore::place_buffered`]; callers that
    /// do not need an owned `Vec` should use the buffered variant.
    pub fn place(&mut self, batch: &[VmSpec]) -> Vec<Decision> {
        self.place_buffered(batch);
        self.ctx.decisions.to_vec()
    }

    /// Allocation-free [`EventCore::place`]: the decisions land in the
    /// context's [`crate::policies::DecisionBuffer`] (read them via
    /// [`EventCore::decisions`]) and stay valid until the next batch.
    ///
    /// With admission queueing enabled, parked requests are re-offered
    /// (FIFO, once per interval, before the fresh batch — expiries
    /// first) and this batch's retryable rejections are parked in turn,
    /// their decisions rewritten to [`RejectReason::Queued`].
    pub fn place_buffered(&mut self, batch: &[VmSpec]) {
        self.process_queue();
        if batch.is_empty() {
            self.ctx.decisions.begin(0);
            return;
        }
        let t_end = self.interval_end();
        self.ctx.now = t_end;
        // Reset the buffer here too (idempotent with the policies' own
        // `begin`): a policy that forgets it must not leave the previous
        // batch's decisions to be zipped against this batch's VMs.
        self.ctx.decisions.begin(batch.len());
        self.policy.place_batch_into(&mut self.dc, batch, &mut self.ctx);
        debug_assert_eq!(self.ctx.decisions.len(), batch.len());
        if self.queue.enabled() {
            self.account_batch_with_queue(batch, t_end);
        } else {
            for (vm, d) in batch.iter().zip(self.ctx.decisions.as_slice()) {
                self.requested += 1;
                self.per_profile[vm.profile.dense()].0 += 1;
                match d {
                    Decision::Placed { .. } => {
                        self.accepted += 1;
                        self.per_profile[vm.profile.dense()].1 += 1;
                        self.departures.push(Reverse((vm.departure.max(t_end + 1), vm.id)));
                    }
                    Decision::Rejected(reason) => self.rejections[reason.index()] += 1,
                }
            }
        }
        self.absorb_migrations();
    }

    /// Account one accepted VM (shared by the batch, retry and
    /// preemption paths). Keeps `sum(rejections) == requested -
    /// accepted` callers' responsibility.
    fn accept(&mut self, vm: &VmSpec, t_end: Time) {
        self.accepted += 1;
        self.per_profile[vm.profile.dense()].1 += 1;
        self.departures.push(Reverse((vm.departure.max(t_end + 1), vm.id)));
        if self.queue.config().preemption {
            self.resident_specs.insert(vm.id, *vm);
        }
    }

    /// The queue-aware batch accounting pass: retryable rejections are
    /// parked (decision rewritten to `Queued`); with preemption on,
    /// high-tier rejections first try to displace low-tier residents.
    fn account_batch_with_queue(&mut self, batch: &[VmSpec], t_end: Time) {
        let mut ds = self.ctx.decisions.to_vec();
        for (i, vm) in batch.iter().enumerate() {
            self.requested += 1;
            self.per_profile[vm.profile.dense()].0 += 1;
            match ds[i] {
                Decision::Placed { .. } => self.accept(vm, t_end),
                Decision::Rejected(reason) => {
                    let mut d = Decision::Rejected(reason);
                    if reason.retryable() {
                        if self.queue.config().preemption && tier_of(vm) == Tier::High {
                            if let Some(placed) = self.try_preempt(vm, t_end) {
                                d = placed;
                            }
                        }
                        if !d.is_placed() && self.queue.try_enqueue(*vm, t_end) {
                            d = Decision::Rejected(RejectReason::Queued);
                        }
                    }
                    if let Decision::Rejected(r) = d {
                        self.rejections[r.index()] += 1;
                    }
                    ds[i] = d;
                }
            }
        }
        // The preemption re-offers clobbered the decision buffer —
        // restore the batch's (rewritten) decisions for the caller.
        self.ctx.decisions.begin(ds.len());
        for d in ds {
            self.ctx.decisions.push(d);
        }
    }

    /// Once-per-interval queue pass: expire overdue requests, then
    /// re-offer the remainder to the policy in FIFO order. Runs before
    /// the interval's fresh batch (queued requests are older).
    fn process_queue(&mut self) {
        if !self.queue.enabled() || self.queue_done_hour == self.hour {
            return;
        }
        self.queue_done_hour = self.hour;
        let t_end = self.interval_end();
        let rejections = &mut self.rejections;
        self.queue.pop_expired(t_end, |_| {
            rejections[RejectReason::Queued.index()] -= 1;
            rejections[RejectReason::Expired.index()] += 1;
        });
        if self.queue.is_empty() {
            return;
        }
        let mut scratch = std::mem::take(&mut self.retry_scratch);
        self.queue.drain_into(&mut scratch);
        for req in scratch.drain(..) {
            self.ctx.now = t_end;
            self.policy.place_batch_into(&mut self.dc, std::slice::from_ref(&req.spec), &mut self.ctx);
            debug_assert_eq!(self.ctx.decisions.len(), 1);
            let d = self.ctx.decisions.as_slice()[0];
            match d {
                Decision::Placed { .. } => {
                    // `requested` was counted at arrival; the park flips
                    // back into an acceptance.
                    self.rejections[RejectReason::Queued.index()] -= 1;
                    self.queue_delays.push(t_end.saturating_sub(req.enqueued));
                    self.accept(&req.spec, t_end);
                }
                Decision::Rejected(_) => self.queue.restore(req),
            }
        }
        self.retry_scratch = scratch;
        self.absorb_migrations();
    }

    /// Try to place a rejected high-tier request by preempting low-tier
    /// residents: first ascending model-compatible GPU where evicting
    /// low-tier VMs (ascending id) yields a block/CPU/RAM fit. Victims
    /// are re-enqueued with fresh TTLs; the request is then re-offered
    /// to the policy. Returns the placed decision, or `None` (victims,
    /// if any were taken, stay queued — they retry next interval).
    fn try_preempt(&mut self, vm: &VmSpec, t_end: Time) -> Option<Decision> {
        let model = vm.profile.model();
        let mut chosen: Option<Vec<VmId>> = None;
        'scan: for h in self.dc.hosts() {
            for (g, gpu) in h.gpus().iter().enumerate() {
                if gpu.model() != model || !h.gpu_available(g) {
                    continue;
                }
                let mut occ = gpu.occupancy();
                let mut cpus = h.free_cpus();
                let mut ram = h.free_ram();
                let mut victims: Vec<VmId> = Vec::new();
                let mut insts: Vec<Instance> = gpu.instances().to_vec();
                insts.sort_by_key(|i| i.vm);
                let mut candidates = insts.iter();
                loop {
                    if cpus >= vm.cpus && ram >= vm.ram_gb && mock_assign(occ, vm.profile).is_some()
                    {
                        if victims.is_empty() {
                            // Fits without evictions: the policy rejected
                            // for its own reasons — nothing to preempt.
                            break;
                        }
                        chosen = Some(victims);
                        break 'scan;
                    }
                    let Some(inst) = candidates.next() else { break };
                    let low_tier = self
                        .resident_specs
                        .get(&inst.vm)
                        .map(|s| tier_of(s) == Tier::Low)
                        .unwrap_or(false);
                    if !low_tier {
                        continue;
                    }
                    victims.push(inst.vm);
                    occ &= !inst.placement.mask();
                    let (c, r) = self.dc.vm_demands(inst.vm).unwrap_or((0, 0));
                    cpus += c;
                    ram += r;
                }
            }
        }
        for victim in chosen? {
            self.preempt(victim, t_end);
        }
        self.ctx.now = t_end;
        self.policy.place_batch_into(&mut self.dc, std::slice::from_ref(vm), &mut self.ctx);
        debug_assert_eq!(self.ctx.decisions.len(), 1);
        let d = self.ctx.decisions.as_slice()[0];
        match d {
            Decision::Placed { .. } => {
                self.accept(vm, t_end);
                Some(d)
            }
            Decision::Rejected(_) => None,
        }
    }

    /// Displace one low-tier resident back into the queue: its
    /// acceptance is unwound into a `Queued` rejection (fresh TTL) and
    /// its departure-heap entry revoked. A full queue makes the
    /// displacement terminal (`Expired`) — either way `sum(rejections)
    /// == requested - accepted` is preserved.
    fn preempt(&mut self, vm: VmId, t_end: Time) {
        let spec = self.resident_specs.remove(&vm).expect("preemption tracks resident specs");
        self.dc.remove(vm);
        self.policy.on_departure(&mut self.dc, vm, &mut self.ctx);
        *self.revoked.entry(vm).or_insert(0) += 1;
        self.accepted -= 1;
        self.per_profile[spec.profile.dense()].1 -= 1;
        self.preempted += 1;
        if self.queue.try_enqueue(spec, t_end) {
            self.rejections[RejectReason::Queued.index()] += 1;
        } else {
            self.rejections[RejectReason::Expired.index()] += 1;
        }
    }

    /// Decisions of the latest batch, in request order (empty before the
    /// first batch and after an empty one).
    pub fn decisions(&self) -> &[Decision] {
        self.ctx.decisions.as_slice()
    }

    /// Close the open interval: fire the maintenance tick, take the
    /// metric sample, advance the clock. The sample reads the data
    /// center's O(1) activity counters — no per-interval fleet scan.
    pub fn close_interval(&mut self) {
        let t_end = self.interval_end();
        self.ctx.now = t_end;
        self.policy.on_tick(&mut self.dc, &mut self.ctx);
        self.absorb_migrations();
        for (acc, (active, total)) in
            self.gpu_activity.iter_mut().zip(self.dc.active_gpus_by_model())
        {
            acc.0 += active as u64;
            acc.1 += total as u64;
        }
        // O(1) counter reads, keeping the interval loop scan-free.
        let fleet: usize = self.dc.gpus_by_model().iter().sum();
        self.gpu_intervals_total += fleet as u64;
        self.gpu_intervals_available += (fleet - self.dc.offline_gpus()) as u64;
        self.samples.push(Sample {
            hour: self.hour,
            active_rate: self.dc.active_hardware_rate(),
            acceptance_rate: acceptance_rate(self.accepted, self.requested),
            resident: self.dc.resident_count(),
        });
        if self.integrity_every > 0 && self.hour % self.integrity_every == 0 {
            if let Err(report) = self.dc.try_check_integrity() {
                self.repair_state(report);
            }
        }
        self.hour += 1;
    }

    /// Graceful degradation on a failed integrity check (the
    /// `--on-corruption` contract):
    ///
    /// * `Abort` — panic, the historical behavior.
    /// * `Quarantine` — rebuild the derived indices, then evict the
    ///   offending host's residents (interrupted, like a hardware
    ///   failure) and ban the host; unattributable corruption falls back
    ///   to a plain rebuild.
    /// * `Rebuild` — rebuild the derived indices in place, keep all
    ///   hardware in service.
    ///
    /// Every non-abort repair is logged as an [`OpsEvent::StateRepair`]
    /// with the interval-end timestamp.
    fn repair_state(&mut self, report: IntegrityReport) {
        let t_end = self.interval_end();
        match self.on_corruption {
            OnCorruption::Abort => panic!("datacenter integrity: {report}"),
            OnCorruption::Quarantine => {
                // Repair the indices first: eviction walks them, and the
                // very corruption being handled may sit inside them.
                self.dc.rebuild_derived();
                let host = match report.host {
                    Some(h) => {
                        for vm in self.dc.vms_on_host(h) {
                            self.evict(vm);
                        }
                        self.dc.set_host_health(h, HealthState::Banned);
                        h
                    }
                    None => STATE_REPAIR_NO_HOST,
                };
                self.repairs.push((t_end, OpsEvent::StateRepair { host }));
                debug_assert!(self.dc.check_integrity().is_ok(), "quarantine left bad state");
            }
            OnCorruption::Rebuild => {
                self.dc.rebuild_derived();
                let host = report.host.unwrap_or(STATE_REPAIR_NO_HOST);
                self.repairs.push((t_end, OpsEvent::StateRepair { host }));
                debug_assert!(self.dc.check_integrity().is_ok(), "rebuild left bad state");
            }
        }
    }

    /// One full interval: departures, arrivals, tick, sample. Compat
    /// wrapper around [`EventCore::step_buffered`].
    pub fn step(&mut self, batch: &[VmSpec]) -> Vec<Decision> {
        self.step_buffered(batch);
        self.ctx.decisions.to_vec()
    }

    /// Allocation-free [`EventCore::step`]: returns the batch's
    /// decisions as a slice into the context's decision buffer.
    pub fn step_buffered(&mut self, batch: &[VmSpec]) -> &[Decision] {
        self.release_due(self.interval_end());
        self.place_buffered(batch);
        self.close_interval();
        self.ctx.decisions.as_slice()
    }

    /// Run empty intervals until `window` is the open interval. Lets the
    /// coordinator catch up on request-free intervals exactly as the
    /// simulator would have (departures released per interval, ticks at
    /// every boundary).
    pub fn run_until(&mut self, window: u64) {
        while self.hour < window {
            self.step_buffered(&[]);
        }
    }

    /// VMs evicted by hardware failures so far.
    pub fn interrupted(&self) -> u64 {
        self.interrupted
    }

    /// VMs preempted back into the queue so far.
    pub fn preempted(&self) -> u64 {
        self.preempted
    }

    /// Currently parked requests.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Read access to the admission queue (invariant checks in tests).
    pub fn admission_queue(&self) -> &AdmissionQueue {
        &self.queue
    }

    /// GPU-interval availability accumulators `(schedulable, total)`.
    /// The sharded runner sums these across shards before consuming the
    /// cores, so the merged availability uses one global denominator.
    pub fn availability_counters(&self) -> (u64, u64) {
        (self.gpu_intervals_available, self.gpu_intervals_total)
    }

    /// Hand a resident VM over to another core (the sharded runner's
    /// cross-shard consolidation): release it here — revoking its
    /// departure-heap entry — and return its former location. Unlike a
    /// departure or eviction, the VM keeps running elsewhere, so
    /// `accepted` stays counted here and the move is *not* an
    /// interruption. Returns `None` if the VM is not resident.
    pub fn transfer_out(&mut self, vm: VmId) -> Option<crate::cluster::VmLocation> {
        let loc = self.dc.remove(vm)?;
        self.policy.on_departure(&mut self.dc, vm, &mut self.ctx);
        *self.revoked.entry(vm).or_insert(0) += 1;
        if !self.resident_specs.is_empty() {
            self.resident_specs.remove(&vm);
        }
        Some(loc)
    }

    /// Adopt a VM transferred from another core: place it on the given
    /// GPU (the caller validated feasibility via `probe_gpu`) and track
    /// its departure locally from now on. The acceptance stays counted
    /// on the core that admitted the VM.
    pub fn adopt(&mut self, spec: &VmSpec, gpu: GpuRef, placement: Placement) {
        self.dc.place(spec, gpu, placement);
        self.departures.push(Reverse((spec.departure.max(self.interval_end() + 1), spec.id)));
        if self.queue.config().preemption {
            self.resident_specs.insert(spec.id, *spec);
        }
    }

    /// Serialize the complete mutable run state into a flat payload for
    /// the crash-safe persistence layer ([`crate::recover`]). Everything
    /// a resumed run needs is here — cluster ground truth, policy and
    /// injector state, the RNG stream position, every counter — except
    /// the policy *object* itself, which the restoring side rebuilds
    /// from configuration and hands to [`EventCore::restore_bytes`].
    ///
    /// Determinism: all map-backed collections are written in sorted key
    /// order, so snapshotting the same logical state twice yields
    /// byte-identical payloads.
    pub fn snapshot_bytes(&self) -> Vec<u8> {
        let mut e = Enc::with_capacity(4096);
        e.u64(self.interval);
        e.u64(self.integrity_every);
        e.u64(self.hour);
        self.dc.snapshot_into(&mut e);
        // Policy context: clock + exact RNG stream position.
        e.u64(self.ctx.now);
        let (state, inc, spare) = self.ctx.rng.state_parts();
        e.u64(state);
        e.u64(inc);
        match spare {
            Some(v) => {
                e.bool(true);
                e.f64(v);
            }
            None => e.bool(false),
        }
        // Policy: name (verified on restore) + its opaque state.
        e.str(self.policy.name());
        let mut pstate = Vec::new();
        self.policy.snapshot_state(&mut pstate);
        e.blob(&pstate);
        // Departure heap, as a sorted list (heap order is not unique;
        // the sorted form is canonical and rebuilds the same heap
        // behavior — equal (time, vm) entries are interchangeable).
        let mut deps: Vec<(Time, VmId)> = self.departures.iter().map(|r| r.0).collect();
        deps.sort_unstable();
        e.usize(deps.len());
        for (t, vm) in deps {
            e.u64(t);
            e.u64(vm);
        }
        e.usize(self.samples.len());
        for s in &self.samples {
            e.u64(s.hour);
            e.f64(s.active_rate);
            e.f64(s.acceptance_rate);
            e.usize(s.resident);
        }
        e.u64(self.requested);
        e.u64(self.accepted);
        for &(req, acc) in &self.per_profile {
            e.u64(req);
            e.u64(acc);
        }
        for &r in &self.rejections {
            e.u64(r);
        }
        e.usize(self.migrations.len());
        for ev in &self.migrations {
            ev.encode(&mut e);
        }
        e.u64(self.migration_cost[0]);
        e.u64(self.migration_cost[1]);
        for &(active, total) in &self.gpu_activity {
            e.u64(active);
            e.u64(total);
        }
        // Fault injector: schedule + replay cursor + failure tally.
        let (schedule, cursor, failures, ban_after) = self.injector.snapshot_parts();
        e.usize(schedule.len());
        for (t, ev) in schedule {
            e.u64(*t);
            ev.encode(&mut e);
        }
        e.usize(cursor);
        e.usize(failures.len());
        for ((host, gpu), n) in failures {
            e.u32(host);
            e.u8(gpu);
            e.u32(n);
        }
        e.u32(ban_after);
        // Admission queue: config + parked requests in FIFO order.
        let qc = self.queue.config();
        e.usize(qc.capacity);
        e.u64(qc.ttl_hours);
        e.bool(qc.preemption);
        e.usize(self.queue.len());
        for req in self.queue.iter() {
            req.spec.encode(&mut e);
            e.u64(req.enqueued);
            e.u64(req.deadline);
        }
        e.u64(self.queue_done_hour);
        let mut revoked: Vec<(VmId, u32)> = self.revoked.iter().map(|(&k, &v)| (k, v)).collect();
        revoked.sort_unstable_by_key(|&(k, _)| k);
        e.usize(revoked.len());
        for (vm, n) in revoked {
            e.u64(vm);
            e.u32(n);
        }
        let mut specs: Vec<&VmSpec> = self.resident_specs.values().collect();
        specs.sort_unstable_by_key(|s| s.id);
        e.usize(specs.len());
        for s in specs {
            s.encode(&mut e);
        }
        e.u64(self.interrupted);
        e.u64(self.preempted);
        e.usize(self.queue_delays.len());
        for &d in &self.queue_delays {
            e.u64(d);
        }
        e.usize(self.gap_samples.len());
        for &g in &self.gap_samples {
            e.f64(g);
        }
        e.u64(self.gpu_intervals_available);
        e.u64(self.gpu_intervals_total);
        e.usize(self.repairs.len());
        for (t, ev) in &self.repairs {
            e.u64(*t);
            ev.encode(&mut e);
        }
        e.into_bytes()
    }

    /// Rebuild a core from a [`EventCore::snapshot_bytes`] payload. The
    /// caller supplies a freshly-built policy of the same registry name
    /// and configuration as the snapshotted run; its name is verified
    /// against the payload and its state restored through
    /// [`Policy::restore_state`]. `on_corruption` intentionally resets
    /// to the default — it is a run *option*, reapplied by the engine.
    pub fn restore_bytes(bytes: &[u8], mut policy: Box<dyn Policy>) -> Result<EventCore, String> {
        let mut d = Dec::new(bytes);
        let interval = d.u64()?;
        let integrity_every = d.u64()?;
        let hour = d.u64()?;
        let dc = DataCenter::restore_from(&mut d)?;
        let now = d.u64()?;
        let rng_state = d.u64()?;
        let rng_inc = d.u64()?;
        let rng_spare = if d.bool()? { Some(d.f64()?) } else { None };
        let mut ctx = PolicyCtx::new(0);
        ctx.now = now;
        ctx.rng = Rng::from_state_parts(rng_state, rng_inc, rng_spare);
        let name = d.str()?;
        if policy.name() != name {
            return Err(format!(
                "snapshot was taken under policy {name:?} but {:?} was supplied",
                policy.name()
            ));
        }
        let pstate = d.blob()?.to_vec();
        policy.restore_state(&pstate)?;
        let n = d.count(16)?;
        let mut departures = BinaryHeap::with_capacity(n);
        for _ in 0..n {
            let t = d.u64()?;
            let vm = d.u64()?;
            departures.push(Reverse((t, vm)));
        }
        let n = d.count(32)?;
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n {
            samples.push(Sample {
                hour: d.u64()?,
                active_rate: d.f64()?,
                acceptance_rate: d.f64()?,
                resident: d.usize()?,
            });
        }
        let requested = d.u64()?;
        let accepted = d.u64()?;
        let mut per_profile = [(0u64, 0u64); NUM_PROFILE_KEYS];
        for slot in &mut per_profile {
            slot.0 = d.u64()?;
            slot.1 = d.u64()?;
        }
        let mut rejections: RejectCounts = [0; 6];
        for slot in &mut rejections {
            *slot = d.u64()?;
        }
        let n = d.count(21)?;
        let mut migrations = Vec::with_capacity(n);
        for _ in 0..n {
            migrations.push(MigrationEvent::decode(&mut d)?);
        }
        let migration_cost = [d.u64()?, d.u64()?];
        let mut gpu_activity = [(0u64, 0u64); NUM_MODELS];
        for slot in &mut gpu_activity {
            slot.0 = d.u64()?;
            slot.1 = d.u64()?;
        }
        let n = d.count(13)?;
        let mut schedule = Vec::with_capacity(n);
        for _ in 0..n {
            let t = d.u64()?;
            schedule.push((t, OpsEvent::decode(&mut d)?));
        }
        let cursor = d.usize()?;
        if cursor > schedule.len() {
            return Err(format!("injector cursor {cursor} beyond schedule of {}", schedule.len()));
        }
        let n = d.count(9)?;
        let mut failures = Vec::with_capacity(n);
        for _ in 0..n {
            let host = d.u32()?;
            let gpu = d.u8()?;
            let count = d.u32()?;
            failures.push(((host, gpu), count));
        }
        let ban_after = d.u32()?;
        let injector = FaultInjector::from_snapshot(schedule, cursor, failures, ban_after);
        let queue_cfg = QueueConfig {
            capacity: d.usize()?,
            ttl_hours: d.u64()?,
            preemption: d.bool()?,
        };
        let mut queue = AdmissionQueue::new(queue_cfg);
        let n = d.count(57)?;
        for _ in 0..n {
            let spec = VmSpec::decode(&mut d)?;
            let enqueued = d.u64()?;
            let deadline = d.u64()?;
            queue.restore(QueuedRequest { spec, enqueued, deadline });
        }
        let queue_done_hour = d.u64()?;
        let n = d.count(12)?;
        let mut revoked = HashMap::with_capacity(n);
        for _ in 0..n {
            let vm = d.u64()?;
            let count = d.u32()?;
            revoked.insert(vm, count);
        }
        let n = d.count(41)?;
        let mut resident_specs = HashMap::with_capacity(n);
        for _ in 0..n {
            let spec = VmSpec::decode(&mut d)?;
            resident_specs.insert(spec.id, spec);
        }
        let interrupted = d.u64()?;
        let preempted = d.u64()?;
        let n = d.count(8)?;
        let mut queue_delays = Vec::with_capacity(n);
        for _ in 0..n {
            queue_delays.push(d.u64()?);
        }
        let n = d.count(8)?;
        let mut gap_samples = Vec::with_capacity(n);
        for _ in 0..n {
            gap_samples.push(d.f64()?);
        }
        let gpu_intervals_available = d.u64()?;
        let gpu_intervals_total = d.u64()?;
        let n = d.count(13)?;
        let mut repairs = Vec::with_capacity(n);
        for _ in 0..n {
            let t = d.u64()?;
            repairs.push((t, OpsEvent::decode(&mut d)?));
        }
        if !d.is_empty() {
            return Err(format!("{} trailing bytes after the core snapshot", d.remaining()));
        }
        Ok(EventCore {
            dc,
            policy,
            ctx,
            interval,
            integrity_every,
            departures,
            hour,
            samples,
            requested,
            accepted,
            per_profile,
            rejections,
            migrations,
            migration_cost,
            gpu_activity,
            injector,
            queue,
            queue_done_hour,
            retry_scratch: Vec::new(),
            revoked,
            resident_specs,
            interrupted,
            preempted,
            queue_delays,
            gap_samples,
            gpu_intervals_available,
            gpu_intervals_total,
            on_corruption: OnCorruption::default(),
            repairs,
        })
    }

    /// Finish: package everything into the shared result type. Requests
    /// still parked in the queue never served — they flush to
    /// [`RejectReason::Expired`], keeping `sum(rejections) == requested
    /// - accepted` in the result.
    pub fn into_result(mut self, wall_seconds: f64) -> SimResult {
        let mut leftovers = Vec::new();
        self.queue.drain_into(&mut leftovers);
        for _ in &leftovers {
            self.rejections[RejectReason::Queued.index()] -= 1;
            self.rejections[RejectReason::Expired.index()] += 1;
        }
        let availability = if self.gpu_intervals_total == 0 {
            1.0
        } else {
            self.gpu_intervals_available as f64 / self.gpu_intervals_total as f64
        };
        SimResult {
            policy: self.policy.name().to_string(),
            samples: self.samples,
            requested: self.requested,
            accepted: self.accepted,
            per_profile: self.per_profile,
            rejections: self.rejections,
            migration_events: self.migrations,
            gpus_by_model: self.dc.gpus_by_model(),
            gpu_activity: self.gpu_activity,
            interrupted: self.interrupted,
            preempted: self.preempted,
            queue_delays: self.queue_delays,
            availability,
            gap_samples: self.gap_samples,
            wall_seconds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;
    use crate::mig::Profile;
    use crate::policies::first_fit::FirstFit;
    use crate::policies::RejectReason;

    fn core(gpus: usize) -> EventCore {
        EventCore::new(
            DataCenter::new(vec![Host::new(0, 64, 256, gpus)]),
            Box::new(FirstFit::new()),
            PolicyCtx::default(),
        )
    }

    fn vm(id: VmId, profile: Profile, arrival: Time, departure: Time) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival, departure, weight: 1.0 }
    }

    #[test]
    fn windows_partition_the_clock() {
        let c = core(1);
        assert_eq!(c.window_of(0), 0);
        assert_eq!(c.window_of(1), 0);
        assert_eq!(c.window_of(HOUR), 0);
        assert_eq!(c.window_of(HOUR + 1), 1);
        assert_eq!(c.window_of(2 * HOUR), 1);
    }

    #[test]
    fn departures_released_before_next_window_arrivals() {
        let mut c = core(1);
        // Placed in interval 0, departs at 100 → deadline clamps to the
        // start of interval 1.
        c.step(&[vm(1, Profile::P7g40gb, 10, 100)]);
        assert_eq!(c.pending_departures(), 1);
        let d = c.step(&[vm(2, Profile::P7g40gb, HOUR + 5, 9 * HOUR)]);
        assert!(d[0].is_placed(), "freed GPU must be reusable");
    }

    #[test]
    fn empty_steps_sample_and_advance() {
        let mut c = core(1);
        c.run_until(3);
        assert_eq!(c.hour(), 3);
        let r = c.into_result(0.0);
        assert_eq!(r.samples.len(), 3);
        assert_eq!(r.requested, 0);
        // Empty-denominator convention: vacuous acceptance is 1.0.
        assert!((r.samples[0].acceptance_rate - 1.0).abs() < 1e-12);
    }

    #[test]
    fn buffered_and_vec_paths_agree() {
        let mut c = core(2);
        c.reserve_for_trace(4, 4);
        let d = c.step(&[vm(1, Profile::P3g20gb, 10, 100)]);
        // The compat Vec is a copy of the context's decision buffer.
        assert_eq!(d.as_slice(), c.decisions());
        let d2 = c.step_buffered(&[vm(2, Profile::P3g20gb, HOUR + 5, 9 * HOUR)]).to_vec();
        assert!(d2[0].is_placed());
        assert_eq!(c.decisions(), d2.as_slice());
        // An empty batch clears the buffer (no stale decisions).
        c.step_buffered(&[]);
        assert!(c.decisions().is_empty());
    }

    #[test]
    fn rejection_reasons_accumulate() {
        let mut c = core(1);
        c.step(&[vm(1, Profile::P7g40gb, 0, 99 * HOUR), vm(2, Profile::P1g5gb, 0, 99 * HOUR)]);
        let rej = c.rejections();
        assert_eq!(rej[RejectReason::NoGpuFit.index()], 1);
        assert_eq!(rej.iter().sum::<u64>(), 1);
    }

    fn wvm(id: VmId, profile: Profile, arrival: Time, departure: Time, weight: f64) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival, departure, weight }
    }

    #[test]
    fn gpu_failure_interrupts_blocks_and_repairs() {
        let mut c = core(1);
        c.set_integrity_every(1);
        let r = crate::cluster::GpuRef { host: 0, gpu: 0 };
        c.set_fault_schedule(FaultInjector::new(
            vec![
                (HOUR + 10, OpsEvent::GpuFail { gpu: r, until: 3 * HOUR + 10 }),
                (3 * HOUR + 10, OpsEvent::GpuRepair { gpu: r }),
            ],
            0,
        ));
        // Hour 0: placed on the (healthy) GPU.
        let d = c.step(&[vm(1, Profile::P7g40gb, 10, 100 * HOUR)]);
        assert!(d[0].is_placed());
        // Hour 1: the failure applies before the batch — the resident is
        // interrupted and the arrival finds no schedulable GPU.
        let d = c.step(&[vm(2, Profile::P7g40gb, HOUR + 20, 100 * HOUR)]);
        assert_eq!(c.interrupted(), 1);
        assert_eq!(c.dc.resident_count(), 0);
        assert_eq!(d[0], Decision::Rejected(RejectReason::NoGpuFit));
        c.step(&[]); // hour 2: still down
        // Hour 3: repaired before the batch — placements resume.
        let d = c.step(&[vm(3, Profile::P7g40gb, 3 * HOUR + 20, 100 * HOUR)]);
        assert!(d[0].is_placed());
        // Interruption is not a rejection: the invariant stays exact.
        assert_eq!(c.rejections().iter().sum::<u64>(), c.requested() - c.accepted());
        let r = c.into_result(0.0);
        assert_eq!(r.interrupted, 1);
        // Availability: 4 sampled intervals, GPU offline in two of them.
        assert!((r.availability - 0.5).abs() < 1e-12);
    }

    #[test]
    fn queued_request_is_served_when_capacity_frees() {
        let mut c = core(1);
        c.set_admission_queue(QueueConfig { capacity: 4, ttl_hours: 10, preemption: false });
        let d = c.step(&[
            vm(1, Profile::P7g40gb, 10, HOUR + 5),
            vm(2, Profile::P7g40gb, 20, 100 * HOUR),
        ]);
        assert!(d[0].is_placed());
        assert_eq!(d[1], Decision::Rejected(RejectReason::Queued));
        assert_eq!(c.queue_len(), 1);
        c.admission_queue().verify().unwrap();
        // Hour 1: VM 1 departs, the queued request retries and lands.
        c.step(&[]);
        assert_eq!(c.queue_len(), 0);
        assert_eq!(c.accepted(), 2);
        assert_eq!(c.rejections().iter().sum::<u64>(), 0);
        let r = c.into_result(0.0);
        assert_eq!(r.queue_delays, vec![HOUR]);
    }

    #[test]
    fn queued_request_expires_after_ttl() {
        let mut c = core(1);
        c.set_admission_queue(QueueConfig { capacity: 4, ttl_hours: 2, preemption: false });
        c.step(&[
            vm(1, Profile::P7g40gb, 10, 100 * HOUR), // occupies forever
            vm(2, Profile::P7g40gb, 20, 100 * HOUR), // parks
        ]);
        c.step(&[]); // hour 1: retry fails, still parked
        assert_eq!(c.queue_len(), 1);
        c.step(&[]); // hour 2: TTL (2 h from t=1 h) lapses
        assert_eq!(c.queue_len(), 0);
        let r = c.into_result(0.0);
        assert_eq!(r.rejections[RejectReason::Expired.index()], 1);
        assert_eq!(r.rejections[RejectReason::Queued.index()], 0);
        assert_eq!(r.rejections.iter().sum::<u64>(), r.requested - r.accepted);
    }

    #[test]
    fn high_tier_arrival_preempts_low_tier_resident() {
        let mut c = core(1);
        c.set_admission_queue(QueueConfig { capacity: 4, ttl_hours: 10, preemption: true });
        let d = c.step(&[
            wvm(1, Profile::P7g40gb, 10, 100 * HOUR, 1.0),
            wvm(2, Profile::P7g40gb, 20, 100 * HOUR, 2.5),
        ]);
        assert!(d[0].is_placed());
        assert!(d[1].is_placed(), "high tier displaces the low-tier resident");
        assert_eq!(c.preempted(), 1);
        assert_eq!(c.accepted(), 1); // VM 1's acceptance was unwound
        assert_eq!(c.queue_len(), 1); // ...back into the queue
        assert_eq!(c.rejections()[RejectReason::Queued.index()], 1);
        assert_eq!(c.rejections().iter().sum::<u64>(), c.requested() - c.accepted());
        c.dc.check_integrity().unwrap();
        let r = c.into_result(0.0);
        assert_eq!(r.preempted, 1);
        // The still-parked victim flushes to Expired in the result.
        assert_eq!(r.rejections[RejectReason::Expired.index()], 1);
    }

    /// Build a core with queueing and a fault schedule, drive it partway,
    /// snapshot, and check both locks of the recovery contract: the
    /// restored twin re-snapshots to byte-identical bytes, and driving
    /// twin and original through the same remaining trace yields
    /// `same_outcome` results.
    #[test]
    fn snapshot_restore_round_trip_is_deterministic() {
        let build = || {
            let mut c = EventCore::new(
                DataCenter::new(vec![Host::new(0, 64, 256, 2), Host::new(1, 64, 256, 2)]),
                Box::new(FirstFit::new()),
                PolicyCtx::new(7),
            );
            c.set_admission_queue(QueueConfig { capacity: 4, ttl_hours: 6, preemption: true });
            c.set_integrity_every(1);
            let g = crate::cluster::GpuRef { host: 1, gpu: 0 };
            c.set_fault_schedule(FaultInjector::new(
                vec![
                    (2 * HOUR + 1, OpsEvent::GpuFail { gpu: g, until: 4 * HOUR }),
                    (4 * HOUR + 1, OpsEvent::GpuRepair { gpu: g }),
                ],
                0,
            ));
            c
        };
        let prefix: Vec<Vec<VmSpec>> = vec![
            vec![
                wvm(1, Profile::P7g40gb, 10, 100 * HOUR, 1.0),
                wvm(2, Profile::P3g20gb, 20, 3 * HOUR, 2.5),
            ],
            vec![wvm(3, Profile::P7g40gb, HOUR + 10, 100 * HOUR, 1.0)],
            vec![
                wvm(4, Profile::P2g10gb, 2 * HOUR + 10, 100 * HOUR, 2.5),
                // Over-subscribe so the snapshot carries parked entries.
                wvm(7, Profile::P7g40gb, 2 * HOUR + 15, 100 * HOUR, 1.0),
                wvm(8, Profile::P7g40gb, 2 * HOUR + 20, 100 * HOUR, 1.0),
                wvm(9, Profile::P7g40gb, 2 * HOUR + 25, 100 * HOUR, 1.0),
            ],
        ];
        let suffix: Vec<Vec<VmSpec>> = vec![
            vec![wvm(5, Profile::P1g5gb, 3 * HOUR + 10, 100 * HOUR, 1.0)],
            vec![],
            vec![wvm(6, Profile::P7g40gb, 5 * HOUR + 10, 100 * HOUR, 2.5)],
        ];

        let mut original = build();
        for batch in &prefix {
            original.step_buffered(batch);
        }
        let snap = original.snapshot_bytes();
        assert!(original.queue_len() > 0, "snapshot should carry parked requests");

        // Lock 1: restore → re-snapshot is byte-identical.
        let twin = EventCore::restore_bytes(&snap, Box::new(FirstFit::new())).unwrap();
        assert_eq!(twin.snapshot_bytes(), snap, "restore must be byte-exact");
        assert_eq!(twin.hour(), original.hour());
        assert_eq!(twin.queue_len(), original.queue_len());

        // Lock 2: both timelines replay the suffix identically.
        let mut twin = twin;
        for batch in &suffix {
            let a = original.step_buffered(batch).to_vec();
            let b = twin.step_buffered(batch).to_vec();
            assert_eq!(a, b, "post-restore decisions diverged");
        }
        let ra = original.into_result(0.0);
        let rb = twin.into_result(1.0);
        assert!(ra.same_outcome(&rb), "resumed run must match uninterrupted run");
    }

    #[test]
    fn restore_rejects_policy_mismatch_and_corruption() {
        let mut c = core(2);
        c.step(&[vm(1, Profile::P3g20gb, 10, 100 * HOUR)]);
        let snap = c.snapshot_bytes();
        // Wrong policy supplied at restore time: refused, not silently
        // re-interpreted (its state bytes would be meaningless).
        let err = EventCore::restore_bytes(&snap, Box::new(crate::policies::mcc::Mcc::new()))
            .unwrap_err();
        assert!(err.contains("policy"), "unexpected error: {err}");
        // A flipped payload byte must surface as a decode error, never a
        // silently wrong state.
        let mut bad = snap.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xff;
        assert!(EventCore::restore_bytes(&bad, Box::new(FirstFit::new())).is_err());
    }

    /// Satellite regression: a queued request whose TTL lapses *exactly*
    /// at a retry interval's boundary is expired, not retried — even if
    /// capacity freed up that same interval.
    #[test]
    fn ttl_expiring_exactly_at_retry_boundary_counts_expired() {
        let mut c = core(1);
        c.set_admission_queue(QueueConfig { capacity: 4, ttl_hours: 1, preemption: false });
        c.step(&[
            vm(1, Profile::P7g40gb, 10, HOUR + 5), // departs before hour 1 closes
            vm(2, Profile::P7g40gb, 20, 100 * HOUR), // parks; deadline = 2·HOUR
        ]);
        assert_eq!(c.queue_len(), 1);
        // Hour 1: VM 1's departure frees the GPU, so a retry would
        // succeed — but the deadline == t_end boundary expires first.
        c.step(&[]);
        assert_eq!(c.queue_len(), 0);
        assert_eq!(c.accepted(), 1, "boundary expiry must not be retried");
        let r = c.into_result(0.0);
        assert_eq!(r.rejections[RejectReason::Expired.index()], 1);
        assert_eq!(r.rejections[RejectReason::Queued.index()], 0);
        assert_eq!(r.rejections.iter().sum::<u64>(), r.requested - r.accepted);
    }

    #[test]
    fn quarantine_bans_offending_host_and_logs_repair() {
        let mut c = EventCore::new(
            DataCenter::new(vec![Host::new(0, 64, 256, 1), Host::new(1, 64, 256, 1)]),
            Box::new(FirstFit::new()),
            PolicyCtx::default(),
        );
        c.set_integrity_every(1);
        c.set_on_corruption(OnCorruption::Quarantine);
        let d = c.step(&[
            vm(1, Profile::P7g40gb, 10, 100 * HOUR),
            vm(2, Profile::P7g40gb, 20, 100 * HOUR),
        ]);
        assert!(d[0].is_placed() && d[1].is_placed());
        // Corrupt ground truth on host 0: the derived index still claims
        // VM 1 lives there.
        c.dc.host_mut(0).gpu_mut(0).remove_vm(1);
        assert!(c.dc.try_check_integrity().is_err());
        c.step(&[]); // integrity tick fires at the interval close
        assert_eq!(c.dc.host_health(0), HealthState::Banned);
        assert_eq!(c.state_repairs().len(), 1);
        assert!(matches!(c.state_repairs()[0].1, OpsEvent::StateRepair { host: 0 }));
        c.dc.check_integrity().unwrap();
        // Host 1's resident is untouched; a new arrival can only land
        // there — and host 1 is full, so it rejects.
        let d = c.step(&[vm(3, Profile::P7g40gb, 2 * HOUR + 10, 100 * HOUR)]);
        assert_eq!(d[0], Decision::Rejected(RejectReason::NoGpuFit));
        assert_eq!(c.dc.resident_count(), 1);
    }

    #[test]
    fn rebuild_repairs_in_place_without_banning() {
        let mut c = core(2);
        c.set_integrity_every(1);
        c.set_on_corruption(OnCorruption::Rebuild);
        c.step(&[vm(1, Profile::P7g40gb, 10, 100 * HOUR)]);
        c.dc.host_mut(0).gpu_mut(0).remove_vm(1);
        c.step(&[]);
        assert_eq!(c.dc.host_health(0), HealthState::Healthy);
        assert_eq!(c.state_repairs().len(), 1);
        c.dc.check_integrity().unwrap();
        // The host stays in service: new placements still land.
        let d = c.step(&[vm(2, Profile::P7g40gb, 2 * HOUR + 10, 100 * HOUR)]);
        assert!(d[0].is_placed());
    }
}
