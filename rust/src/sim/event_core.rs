//! The shared event core driving both the offline simulator and the
//! online coordinator.
//!
//! Before the decision-API redesign, `sim::engine` and
//! `coordinator::service` each carried their own departure heap, interval
//! batching, maintenance-tick and metric-sampling loop — and disagreed on
//! details (departure deadlines, empty-denominator conventions). The
//! [`EventCore`] owns that loop once:
//!
//! * a departure min-heap of accepted VMs, released *before* the
//!   interval's arrivals (blocks freed during an interval serve the
//!   interval's requests, as in an online system with immediate
//!   reclamation);
//! * interval-batched placement through the [`Policy`] trait's typed
//!   [`Decision`]s, with per-[`crate::policies::RejectReason`] accounting;
//! * the per-interval maintenance tick (GRMU's consolidation clock) and
//!   hourly metric sample;
//! * collection of the policy's [`MigrationEvent`] records;
//! * replay of the [`crate::ops`] fault/repair/drain schedule (at the
//!   end of every `release_due`, after the interval's departures) with
//!   eviction, all-or-nothing drain evacuation and availability
//!   accounting;
//! * the admission queue's once-per-interval expiry + FIFO retry pass
//!   (before the interval's fresh batch) and, under preemption,
//!   high-tier displacement of low-tier residents. Disabled ops leave
//!   every decision stream byte-identical to the pre-ops core.
//!
//! The simulator calls [`EventCore::step_buffered`] for every interval of
//! a trace; the coordinator calls
//! [`EventCore::run_until`]/[`EventCore::place_buffered`] as requests
//! arrive. Both end in the same [`SimResult`], which is what the
//! simulator-vs-coordinator equivalence test locks down.
//!
//! Since §Perf iteration 6 the steady-state loop is allocation-free and
//! scan-free: decisions land in the [`PolicyCtx`]'s reusable
//! [`crate::policies::DecisionBuffer`] (the `Vec`-returning
//! [`EventCore::step`]/[`EventCore::place`] remain as compat wrappers),
//! migrations drain via [`Policy::drain_migrations_into`] into a
//! pre-sized log, and the per-interval sample reads the data center's
//! O(1) activity counters instead of scanning the fleet.
//! [`EventCore::reserve_for_trace`] pre-sizes the departure heap, sample
//! vector and migration log from trace metadata.

use super::metrics::{acceptance_rate, Sample, SimResult};
use crate::cluster::vm::{Time, VmId, VmSpec, HOUR};
use crate::cluster::{DataCenter, GpuRef, HealthState};
use crate::mig::{mock_assign, Instance, Placement, NUM_MODELS, NUM_PROFILE_KEYS};
use crate::ops::{
    plan_evacuation, tier_of, AdmissionQueue, FaultInjector, OpsEvent, QueueConfig, QueuedRequest,
    Tier,
};
use crate::policies::{Decision, MigrationEvent, Policy, PolicyCtx, RejectCounts, RejectReason};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// The unified departure-heap / batch / tick / sample loop.
pub struct EventCore {
    pub dc: DataCenter,
    pub policy: Box<dyn Policy>,
    pub ctx: PolicyCtx,
    interval: Time,
    /// Run integrity checks every N intervals (0 = disabled). Expensive;
    /// enabled in tests.
    integrity_every: u64,
    /// Departure min-heap of accepted VMs: (time, vm id).
    departures: BinaryHeap<Reverse<(Time, VmId)>>,
    /// Index of the currently open (not yet closed) interval.
    hour: u64,
    samples: Vec<Sample>,
    requested: u64,
    accepted: u64,
    /// Per-profile `(requested, accepted)` by dense cross-model key.
    per_profile: [(u64, u64); NUM_PROFILE_KEYS],
    rejections: RejectCounts,
    migrations: Vec<MigrationEvent>,
    /// Cumulative block-weighted migration cost per
    /// [`crate::policies::MigrationKind`] (by `MigrationKind::index`),
    /// accumulated as events are absorbed so online readers (the
    /// coordinator's stats endpoint) get it in O(1).
    migration_cost: [u64; 2],
    /// Cumulative per-model `(active, total)` GPU-interval counts,
    /// accumulated at every sample (the per-model active-hardware
    /// breakdown of heterogeneous fleets).
    gpu_activity: [(u64, u64); NUM_MODELS],
    /// Scheduled operational events (faults/repairs/drains), replayed at
    /// the end of every [`EventCore::release_due`]. Empty by default.
    injector: FaultInjector,
    /// Bounded retry queue for retryable rejections; disabled by default.
    queue: AdmissionQueue,
    /// Interval already queue-processed (guards the coordinator's
    /// several `place_buffered` calls per window — the simulator
    /// processes each interval exactly once).
    queue_done_hour: u64,
    /// Reusable FIFO retry-pass buffer.
    retry_scratch: Vec<QueuedRequest>,
    /// Stale departure-heap entries per VM: evictions/preemptions leave
    /// their heap entry behind; `release_due` skips that many pops.
    revoked: HashMap<VmId, u32>,
    /// Specs of resident VMs — maintained only under preemption, which
    /// must know victims' tiers and re-enqueue their full spec.
    resident_specs: HashMap<VmId, VmSpec>,
    /// VMs evicted by hardware failures (terminal; not a rejection).
    interrupted: u64,
    /// VMs preempted back into the queue by high-tier arrivals.
    preempted: u64,
    /// Queueing delay (seconds) of each request served from the queue.
    queue_delays: Vec<u64>,
    /// Optimality-gap samples drained from the policy (only a
    /// gap-metered policy produces any).
    gap_samples: Vec<f64>,
    /// GPU-interval availability accumulator: (schedulable, total).
    gpu_intervals_available: u64,
    gpu_intervals_total: u64,
}

impl EventCore {
    /// A core with hourly intervals (the paper's discrete clock).
    pub fn new(dc: DataCenter, policy: Box<dyn Policy>, ctx: PolicyCtx) -> EventCore {
        EventCore::with_interval(dc, policy, ctx, HOUR)
    }

    pub fn with_interval(
        dc: DataCenter,
        policy: Box<dyn Policy>,
        ctx: PolicyCtx,
        interval: Time,
    ) -> EventCore {
        EventCore {
            dc,
            policy,
            ctx,
            interval: interval.max(1),
            integrity_every: 0,
            departures: BinaryHeap::new(),
            hour: 0,
            samples: Vec::new(),
            requested: 0,
            accepted: 0,
            per_profile: [(0, 0); NUM_PROFILE_KEYS],
            rejections: [0; 6],
            migrations: Vec::new(),
            migration_cost: [0; 2],
            gpu_activity: [(0, 0); NUM_MODELS],
            injector: FaultInjector::default(),
            queue: AdmissionQueue::default(),
            queue_done_hour: u64::MAX,
            retry_scratch: Vec::new(),
            revoked: HashMap::new(),
            resident_specs: HashMap::new(),
            interrupted: 0,
            preempted: 0,
            queue_delays: Vec::new(),
            gap_samples: Vec::new(),
            gpu_intervals_available: 0,
            gpu_intervals_total: 0,
        }
    }

    /// Install a fault/maintenance schedule (see [`crate::ops::fault`]).
    /// Call before the run starts; the default injector is empty and the
    /// replay is a no-op.
    pub fn set_fault_schedule(&mut self, injector: FaultInjector) {
        self.injector = injector;
    }

    /// Configure admission queueing (see [`crate::ops::queue`]). Call
    /// before the run starts; the default (`capacity == 0`) keeps every
    /// rejection terminal and the decision stream byte-identical to the
    /// pre-queue behaviour.
    pub fn set_admission_queue(&mut self, cfg: QueueConfig) {
        self.queue = AdmissionQueue::new(cfg);
    }

    pub fn set_integrity_every(&mut self, every: u64) {
        self.integrity_every = every;
    }

    /// Pre-size the run's collections from trace metadata so the
    /// steady-state loop never grows them: `requests` bounds the
    /// departure heap (every entry is an accepted, still-resident VM) and
    /// `intervals` bounds the sample vector. The migration log gets a
    /// small share of `requests` (§8.3.3 measures migrations ≈ 1% of
    /// accepted VMs); a heavier migration load merely amortizes growth.
    pub fn reserve_for_trace(&mut self, requests: usize, intervals: u64) {
        self.departures.reserve(requests);
        self.samples.reserve(intervals as usize);
        self.migrations.reserve(requests / 32 + 1);
    }

    pub fn interval(&self) -> Time {
        self.interval
    }

    /// Index of the open interval.
    pub fn hour(&self) -> u64 {
        self.hour
    }

    /// End time of the open interval.
    pub fn interval_end(&self) -> Time {
        (self.hour + 1) * self.interval
    }

    /// The interval that owns an arrival at `t`: intervals cover
    /// `(w·interval, (w+1)·interval]`, with `t = 0` in interval 0.
    pub fn window_of(&self, t: Time) -> u64 {
        if t == 0 {
            0
        } else {
            (t - 1) / self.interval
        }
    }

    pub fn pending_departures(&self) -> usize {
        self.departures.len()
    }

    pub fn requested(&self) -> u64 {
        self.requested
    }

    pub fn accepted(&self) -> u64 {
        self.accepted
    }

    /// Rejections so far, indexed by [`crate::policies::RejectReason::index`].
    pub fn rejections(&self) -> RejectCounts {
        self.rejections
    }

    /// Migrations recorded so far.
    pub fn migration_events(&self) -> &[MigrationEvent] {
        &self.migrations
    }

    /// Cumulative block-weighted migration cost so far, indexed by
    /// [`crate::policies::MigrationKind::index`] (`[intra, inter]`).
    pub fn migration_cost(&self) -> [u64; 2] {
        self.migration_cost
    }

    fn absorb_migrations(&mut self) {
        let start = self.migrations.len();
        self.policy.drain_migrations_into(&mut self.migrations);
        for ev in &self.migrations[start..] {
            self.migration_cost[ev.kind.index()] += ev.cost();
        }
        // Piggy-back the gap drain on the same cadence: a no-op for
        // every policy except a gap-metered wrapper.
        self.policy.drain_gap_samples_into(&mut self.gap_samples);
    }

    /// Release departures due by `t` (inclusive), oldest first, then
    /// apply the operational events due by `t` (departures first:
    /// capacity freed during the interval is not pointlessly evicted).
    pub fn release_due(&mut self, t: Time) {
        while let Some(&Reverse((due, vm))) = self.departures.peek() {
            if due > t {
                break;
            }
            self.departures.pop();
            if !self.revoked.is_empty() {
                // An evicted/preempted VM left this entry behind — skip
                // it (a re-placed VM pushed a fresh entry of its own).
                if let Some(n) = self.revoked.get_mut(&vm) {
                    *n -= 1;
                    if *n == 0 {
                        self.revoked.remove(&vm);
                    }
                    continue;
                }
            }
            self.dc.remove(vm);
            self.policy.on_departure(&mut self.dc, vm, &mut self.ctx);
            if !self.resident_specs.is_empty() {
                self.resident_specs.remove(&vm);
            }
        }
        self.apply_ops(t);
    }

    /// Replay scheduled fault/repair/drain events with timestamps ≤ `t`.
    fn apply_ops(&mut self, t: Time) {
        while let Some((due, ev)) = self.injector.pop_due(t) {
            match ev {
                OpsEvent::GpuFail { gpu, until } => {
                    // Evict residents while the index still covers the
                    // device, then take it offline.
                    for vm in self.dc.vms_on_gpu(gpu) {
                        self.evict(vm);
                    }
                    self.dc.set_gpu_health(gpu, HealthState::Failed { until });
                    let _ = self.injector.record_failure(gpu);
                }
                OpsEvent::GpuRepair { gpu } => {
                    let restored = if self.injector.is_banned(gpu) {
                        HealthState::Banned // repeat offender: blocklisted
                    } else {
                        HealthState::Healthy
                    };
                    self.dc.set_gpu_health(gpu, restored);
                }
                OpsEvent::HostFail { host, until } => {
                    for vm in self.dc.vms_on_host(host) {
                        self.evict(vm);
                    }
                    // Correlated (blast-radius) failures can overlap: a
                    // second hit while already down extends the outage,
                    // never shortens it.
                    let until = match self.dc.host_health(host) {
                        HealthState::Failed { until: prev } => prev.max(until),
                        _ => until,
                    };
                    self.dc.set_host_health(host, HealthState::Failed { until });
                }
                OpsEvent::HostRepair { host } => {
                    // A drain that began before the failure stays void;
                    // a repair belonging to a shorter, overlapped outage
                    // must not resurrect a host another failure still
                    // holds down (`until` past this repair's timestamp).
                    if let HealthState::Failed { until } = self.dc.host_health(host) {
                        if until <= due {
                            self.dc.set_host_health(host, HealthState::Healthy);
                        }
                    }
                }
                OpsEvent::DrainStart { host, .. } => {
                    // Only a healthy host can enter maintenance.
                    if self.dc.host_health(host) != HealthState::Healthy {
                        continue;
                    }
                    self.dc.set_host_health(host, HealthState::Draining);
                    // Best-effort, all-or-nothing evacuation through the
                    // transactional planner layer; a refused plan leaves
                    // residents in place (they keep running — draining
                    // allows residency, just no new placements).
                    if let Some(plan) = plan_evacuation(&self.dc, host) {
                        if !plan.is_empty() && self.dc.apply_plan(&plan).is_ok() {
                            let start = self.migrations.len();
                            plan.push_events_into(&mut self.migrations);
                            for ev in &self.migrations[start..] {
                                self.migration_cost[ev.kind.index()] += ev.cost();
                            }
                        }
                    }
                }
                OpsEvent::DrainDone { host } => {
                    // A failure during the drain wins; only a still-
                    // draining host returns to service.
                    if self.dc.host_health(host) == HealthState::Draining {
                        self.dc.set_host_health(host, HealthState::Healthy);
                    }
                }
            }
        }
    }

    /// Evict one VM for a hardware failure: terminal (no re-queue), the
    /// VM counts as interrupted and its departure-heap entry is revoked.
    fn evict(&mut self, vm: VmId) {
        self.dc.remove(vm);
        self.policy.on_departure(&mut self.dc, vm, &mut self.ctx);
        *self.revoked.entry(vm).or_insert(0) += 1;
        self.interrupted += 1;
        if !self.resident_specs.is_empty() {
            self.resident_specs.remove(&vm);
        }
    }

    /// Present `batch` to the policy at the end of the open interval and
    /// account the decisions. A VM placed in interval `w` departs no
    /// earlier than the start of interval `w+1`.
    ///
    /// Compat wrapper around [`EventCore::place_buffered`]; callers that
    /// do not need an owned `Vec` should use the buffered variant.
    pub fn place(&mut self, batch: &[VmSpec]) -> Vec<Decision> {
        self.place_buffered(batch);
        self.ctx.decisions.to_vec()
    }

    /// Allocation-free [`EventCore::place`]: the decisions land in the
    /// context's [`crate::policies::DecisionBuffer`] (read them via
    /// [`EventCore::decisions`]) and stay valid until the next batch.
    ///
    /// With admission queueing enabled, parked requests are re-offered
    /// (FIFO, once per interval, before the fresh batch — expiries
    /// first) and this batch's retryable rejections are parked in turn,
    /// their decisions rewritten to [`RejectReason::Queued`].
    pub fn place_buffered(&mut self, batch: &[VmSpec]) {
        self.process_queue();
        if batch.is_empty() {
            self.ctx.decisions.begin(0);
            return;
        }
        let t_end = self.interval_end();
        self.ctx.now = t_end;
        // Reset the buffer here too (idempotent with the policies' own
        // `begin`): a policy that forgets it must not leave the previous
        // batch's decisions to be zipped against this batch's VMs.
        self.ctx.decisions.begin(batch.len());
        self.policy.place_batch_into(&mut self.dc, batch, &mut self.ctx);
        debug_assert_eq!(self.ctx.decisions.len(), batch.len());
        if self.queue.enabled() {
            self.account_batch_with_queue(batch, t_end);
        } else {
            for (vm, d) in batch.iter().zip(self.ctx.decisions.as_slice()) {
                self.requested += 1;
                self.per_profile[vm.profile.dense()].0 += 1;
                match d {
                    Decision::Placed { .. } => {
                        self.accepted += 1;
                        self.per_profile[vm.profile.dense()].1 += 1;
                        self.departures.push(Reverse((vm.departure.max(t_end + 1), vm.id)));
                    }
                    Decision::Rejected(reason) => self.rejections[reason.index()] += 1,
                }
            }
        }
        self.absorb_migrations();
    }

    /// Account one accepted VM (shared by the batch, retry and
    /// preemption paths). Keeps `sum(rejections) == requested -
    /// accepted` callers' responsibility.
    fn accept(&mut self, vm: &VmSpec, t_end: Time) {
        self.accepted += 1;
        self.per_profile[vm.profile.dense()].1 += 1;
        self.departures.push(Reverse((vm.departure.max(t_end + 1), vm.id)));
        if self.queue.config().preemption {
            self.resident_specs.insert(vm.id, *vm);
        }
    }

    /// The queue-aware batch accounting pass: retryable rejections are
    /// parked (decision rewritten to `Queued`); with preemption on,
    /// high-tier rejections first try to displace low-tier residents.
    fn account_batch_with_queue(&mut self, batch: &[VmSpec], t_end: Time) {
        let mut ds = self.ctx.decisions.to_vec();
        for (i, vm) in batch.iter().enumerate() {
            self.requested += 1;
            self.per_profile[vm.profile.dense()].0 += 1;
            match ds[i] {
                Decision::Placed { .. } => self.accept(vm, t_end),
                Decision::Rejected(reason) => {
                    let mut d = Decision::Rejected(reason);
                    if reason.retryable() {
                        if self.queue.config().preemption && tier_of(vm) == Tier::High {
                            if let Some(placed) = self.try_preempt(vm, t_end) {
                                d = placed;
                            }
                        }
                        if !d.is_placed() && self.queue.try_enqueue(*vm, t_end) {
                            d = Decision::Rejected(RejectReason::Queued);
                        }
                    }
                    if let Decision::Rejected(r) = d {
                        self.rejections[r.index()] += 1;
                    }
                    ds[i] = d;
                }
            }
        }
        // The preemption re-offers clobbered the decision buffer —
        // restore the batch's (rewritten) decisions for the caller.
        self.ctx.decisions.begin(ds.len());
        for d in ds {
            self.ctx.decisions.push(d);
        }
    }

    /// Once-per-interval queue pass: expire overdue requests, then
    /// re-offer the remainder to the policy in FIFO order. Runs before
    /// the interval's fresh batch (queued requests are older).
    fn process_queue(&mut self) {
        if !self.queue.enabled() || self.queue_done_hour == self.hour {
            return;
        }
        self.queue_done_hour = self.hour;
        let t_end = self.interval_end();
        let rejections = &mut self.rejections;
        self.queue.pop_expired(t_end, |_| {
            rejections[RejectReason::Queued.index()] -= 1;
            rejections[RejectReason::Expired.index()] += 1;
        });
        if self.queue.is_empty() {
            return;
        }
        let mut scratch = std::mem::take(&mut self.retry_scratch);
        self.queue.drain_into(&mut scratch);
        for req in scratch.drain(..) {
            self.ctx.now = t_end;
            self.policy.place_batch_into(&mut self.dc, std::slice::from_ref(&req.spec), &mut self.ctx);
            debug_assert_eq!(self.ctx.decisions.len(), 1);
            let d = self.ctx.decisions.as_slice()[0];
            match d {
                Decision::Placed { .. } => {
                    // `requested` was counted at arrival; the park flips
                    // back into an acceptance.
                    self.rejections[RejectReason::Queued.index()] -= 1;
                    self.queue_delays.push(t_end.saturating_sub(req.enqueued));
                    self.accept(&req.spec, t_end);
                }
                Decision::Rejected(_) => self.queue.restore(req),
            }
        }
        self.retry_scratch = scratch;
        self.absorb_migrations();
    }

    /// Try to place a rejected high-tier request by preempting low-tier
    /// residents: first ascending model-compatible GPU where evicting
    /// low-tier VMs (ascending id) yields a block/CPU/RAM fit. Victims
    /// are re-enqueued with fresh TTLs; the request is then re-offered
    /// to the policy. Returns the placed decision, or `None` (victims,
    /// if any were taken, stay queued — they retry next interval).
    fn try_preempt(&mut self, vm: &VmSpec, t_end: Time) -> Option<Decision> {
        let model = vm.profile.model();
        let mut chosen: Option<Vec<VmId>> = None;
        'scan: for h in self.dc.hosts() {
            for (g, gpu) in h.gpus().iter().enumerate() {
                if gpu.model() != model || !h.gpu_available(g) {
                    continue;
                }
                let mut occ = gpu.occupancy();
                let mut cpus = h.free_cpus();
                let mut ram = h.free_ram();
                let mut victims: Vec<VmId> = Vec::new();
                let mut insts: Vec<Instance> = gpu.instances().to_vec();
                insts.sort_by_key(|i| i.vm);
                let mut candidates = insts.iter();
                loop {
                    if cpus >= vm.cpus && ram >= vm.ram_gb && mock_assign(occ, vm.profile).is_some()
                    {
                        if victims.is_empty() {
                            // Fits without evictions: the policy rejected
                            // for its own reasons — nothing to preempt.
                            break;
                        }
                        chosen = Some(victims);
                        break 'scan;
                    }
                    let Some(inst) = candidates.next() else { break };
                    let low_tier = self
                        .resident_specs
                        .get(&inst.vm)
                        .map(|s| tier_of(s) == Tier::Low)
                        .unwrap_or(false);
                    if !low_tier {
                        continue;
                    }
                    victims.push(inst.vm);
                    occ &= !inst.placement.mask();
                    let (c, r) = self.dc.vm_demands(inst.vm).unwrap_or((0, 0));
                    cpus += c;
                    ram += r;
                }
            }
        }
        for victim in chosen? {
            self.preempt(victim, t_end);
        }
        self.ctx.now = t_end;
        self.policy.place_batch_into(&mut self.dc, std::slice::from_ref(vm), &mut self.ctx);
        debug_assert_eq!(self.ctx.decisions.len(), 1);
        let d = self.ctx.decisions.as_slice()[0];
        match d {
            Decision::Placed { .. } => {
                self.accept(vm, t_end);
                Some(d)
            }
            Decision::Rejected(_) => None,
        }
    }

    /// Displace one low-tier resident back into the queue: its
    /// acceptance is unwound into a `Queued` rejection (fresh TTL) and
    /// its departure-heap entry revoked. A full queue makes the
    /// displacement terminal (`Expired`) — either way `sum(rejections)
    /// == requested - accepted` is preserved.
    fn preempt(&mut self, vm: VmId, t_end: Time) {
        let spec = self.resident_specs.remove(&vm).expect("preemption tracks resident specs");
        self.dc.remove(vm);
        self.policy.on_departure(&mut self.dc, vm, &mut self.ctx);
        *self.revoked.entry(vm).or_insert(0) += 1;
        self.accepted -= 1;
        self.per_profile[spec.profile.dense()].1 -= 1;
        self.preempted += 1;
        if self.queue.try_enqueue(spec, t_end) {
            self.rejections[RejectReason::Queued.index()] += 1;
        } else {
            self.rejections[RejectReason::Expired.index()] += 1;
        }
    }

    /// Decisions of the latest batch, in request order (empty before the
    /// first batch and after an empty one).
    pub fn decisions(&self) -> &[Decision] {
        self.ctx.decisions.as_slice()
    }

    /// Close the open interval: fire the maintenance tick, take the
    /// metric sample, advance the clock. The sample reads the data
    /// center's O(1) activity counters — no per-interval fleet scan.
    pub fn close_interval(&mut self) {
        let t_end = self.interval_end();
        self.ctx.now = t_end;
        self.policy.on_tick(&mut self.dc, &mut self.ctx);
        self.absorb_migrations();
        for (acc, (active, total)) in
            self.gpu_activity.iter_mut().zip(self.dc.active_gpus_by_model())
        {
            acc.0 += active as u64;
            acc.1 += total as u64;
        }
        // O(1) counter reads, keeping the interval loop scan-free.
        let fleet: usize = self.dc.gpus_by_model().iter().sum();
        self.gpu_intervals_total += fleet as u64;
        self.gpu_intervals_available += (fleet - self.dc.offline_gpus()) as u64;
        self.samples.push(Sample {
            hour: self.hour,
            active_rate: self.dc.active_hardware_rate(),
            acceptance_rate: acceptance_rate(self.accepted, self.requested),
            resident: self.dc.resident_count(),
        });
        if self.integrity_every > 0 && self.hour % self.integrity_every == 0 {
            self.dc.check_integrity().expect("datacenter integrity");
        }
        self.hour += 1;
    }

    /// One full interval: departures, arrivals, tick, sample. Compat
    /// wrapper around [`EventCore::step_buffered`].
    pub fn step(&mut self, batch: &[VmSpec]) -> Vec<Decision> {
        self.step_buffered(batch);
        self.ctx.decisions.to_vec()
    }

    /// Allocation-free [`EventCore::step`]: returns the batch's
    /// decisions as a slice into the context's decision buffer.
    pub fn step_buffered(&mut self, batch: &[VmSpec]) -> &[Decision] {
        self.release_due(self.interval_end());
        self.place_buffered(batch);
        self.close_interval();
        self.ctx.decisions.as_slice()
    }

    /// Run empty intervals until `window` is the open interval. Lets the
    /// coordinator catch up on request-free intervals exactly as the
    /// simulator would have (departures released per interval, ticks at
    /// every boundary).
    pub fn run_until(&mut self, window: u64) {
        while self.hour < window {
            self.step_buffered(&[]);
        }
    }

    /// VMs evicted by hardware failures so far.
    pub fn interrupted(&self) -> u64 {
        self.interrupted
    }

    /// VMs preempted back into the queue so far.
    pub fn preempted(&self) -> u64 {
        self.preempted
    }

    /// Currently parked requests.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Read access to the admission queue (invariant checks in tests).
    pub fn admission_queue(&self) -> &AdmissionQueue {
        &self.queue
    }

    /// GPU-interval availability accumulators `(schedulable, total)`.
    /// The sharded runner sums these across shards before consuming the
    /// cores, so the merged availability uses one global denominator.
    pub fn availability_counters(&self) -> (u64, u64) {
        (self.gpu_intervals_available, self.gpu_intervals_total)
    }

    /// Hand a resident VM over to another core (the sharded runner's
    /// cross-shard consolidation): release it here — revoking its
    /// departure-heap entry — and return its former location. Unlike a
    /// departure or eviction, the VM keeps running elsewhere, so
    /// `accepted` stays counted here and the move is *not* an
    /// interruption. Returns `None` if the VM is not resident.
    pub fn transfer_out(&mut self, vm: VmId) -> Option<crate::cluster::VmLocation> {
        let loc = self.dc.remove(vm)?;
        self.policy.on_departure(&mut self.dc, vm, &mut self.ctx);
        *self.revoked.entry(vm).or_insert(0) += 1;
        if !self.resident_specs.is_empty() {
            self.resident_specs.remove(&vm);
        }
        Some(loc)
    }

    /// Adopt a VM transferred from another core: place it on the given
    /// GPU (the caller validated feasibility via `probe_gpu`) and track
    /// its departure locally from now on. The acceptance stays counted
    /// on the core that admitted the VM.
    pub fn adopt(&mut self, spec: &VmSpec, gpu: GpuRef, placement: Placement) {
        self.dc.place(spec, gpu, placement);
        self.departures.push(Reverse((spec.departure.max(self.interval_end() + 1), spec.id)));
        if self.queue.config().preemption {
            self.resident_specs.insert(spec.id, *spec);
        }
    }

    /// Finish: package everything into the shared result type. Requests
    /// still parked in the queue never served — they flush to
    /// [`RejectReason::Expired`], keeping `sum(rejections) == requested
    /// - accepted` in the result.
    pub fn into_result(mut self, wall_seconds: f64) -> SimResult {
        let mut leftovers = Vec::new();
        self.queue.drain_into(&mut leftovers);
        for _ in &leftovers {
            self.rejections[RejectReason::Queued.index()] -= 1;
            self.rejections[RejectReason::Expired.index()] += 1;
        }
        let availability = if self.gpu_intervals_total == 0 {
            1.0
        } else {
            self.gpu_intervals_available as f64 / self.gpu_intervals_total as f64
        };
        SimResult {
            policy: self.policy.name().to_string(),
            samples: self.samples,
            requested: self.requested,
            accepted: self.accepted,
            per_profile: self.per_profile,
            rejections: self.rejections,
            migration_events: self.migrations,
            gpus_by_model: self.dc.gpus_by_model(),
            gpu_activity: self.gpu_activity,
            interrupted: self.interrupted,
            preempted: self.preempted,
            queue_delays: self.queue_delays,
            availability,
            gap_samples: self.gap_samples,
            wall_seconds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;
    use crate::mig::Profile;
    use crate::policies::first_fit::FirstFit;
    use crate::policies::RejectReason;

    fn core(gpus: usize) -> EventCore {
        EventCore::new(
            DataCenter::new(vec![Host::new(0, 64, 256, gpus)]),
            Box::new(FirstFit::new()),
            PolicyCtx::default(),
        )
    }

    fn vm(id: VmId, profile: Profile, arrival: Time, departure: Time) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival, departure, weight: 1.0 }
    }

    #[test]
    fn windows_partition_the_clock() {
        let c = core(1);
        assert_eq!(c.window_of(0), 0);
        assert_eq!(c.window_of(1), 0);
        assert_eq!(c.window_of(HOUR), 0);
        assert_eq!(c.window_of(HOUR + 1), 1);
        assert_eq!(c.window_of(2 * HOUR), 1);
    }

    #[test]
    fn departures_released_before_next_window_arrivals() {
        let mut c = core(1);
        // Placed in interval 0, departs at 100 → deadline clamps to the
        // start of interval 1.
        c.step(&[vm(1, Profile::P7g40gb, 10, 100)]);
        assert_eq!(c.pending_departures(), 1);
        let d = c.step(&[vm(2, Profile::P7g40gb, HOUR + 5, 9 * HOUR)]);
        assert!(d[0].is_placed(), "freed GPU must be reusable");
    }

    #[test]
    fn empty_steps_sample_and_advance() {
        let mut c = core(1);
        c.run_until(3);
        assert_eq!(c.hour(), 3);
        let r = c.into_result(0.0);
        assert_eq!(r.samples.len(), 3);
        assert_eq!(r.requested, 0);
        // Empty-denominator convention: vacuous acceptance is 1.0.
        assert!((r.samples[0].acceptance_rate - 1.0).abs() < 1e-12);
    }

    #[test]
    fn buffered_and_vec_paths_agree() {
        let mut c = core(2);
        c.reserve_for_trace(4, 4);
        let d = c.step(&[vm(1, Profile::P3g20gb, 10, 100)]);
        // The compat Vec is a copy of the context's decision buffer.
        assert_eq!(d.as_slice(), c.decisions());
        let d2 = c.step_buffered(&[vm(2, Profile::P3g20gb, HOUR + 5, 9 * HOUR)]).to_vec();
        assert!(d2[0].is_placed());
        assert_eq!(c.decisions(), d2.as_slice());
        // An empty batch clears the buffer (no stale decisions).
        c.step_buffered(&[]);
        assert!(c.decisions().is_empty());
    }

    #[test]
    fn rejection_reasons_accumulate() {
        let mut c = core(1);
        c.step(&[vm(1, Profile::P7g40gb, 0, 99 * HOUR), vm(2, Profile::P1g5gb, 0, 99 * HOUR)]);
        let rej = c.rejections();
        assert_eq!(rej[RejectReason::NoGpuFit.index()], 1);
        assert_eq!(rej.iter().sum::<u64>(), 1);
    }

    fn wvm(id: VmId, profile: Profile, arrival: Time, departure: Time, weight: f64) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival, departure, weight }
    }

    #[test]
    fn gpu_failure_interrupts_blocks_and_repairs() {
        let mut c = core(1);
        c.set_integrity_every(1);
        let r = crate::cluster::GpuRef { host: 0, gpu: 0 };
        c.set_fault_schedule(FaultInjector::new(
            vec![
                (HOUR + 10, OpsEvent::GpuFail { gpu: r, until: 3 * HOUR + 10 }),
                (3 * HOUR + 10, OpsEvent::GpuRepair { gpu: r }),
            ],
            0,
        ));
        // Hour 0: placed on the (healthy) GPU.
        let d = c.step(&[vm(1, Profile::P7g40gb, 10, 100 * HOUR)]);
        assert!(d[0].is_placed());
        // Hour 1: the failure applies before the batch — the resident is
        // interrupted and the arrival finds no schedulable GPU.
        let d = c.step(&[vm(2, Profile::P7g40gb, HOUR + 20, 100 * HOUR)]);
        assert_eq!(c.interrupted(), 1);
        assert_eq!(c.dc.resident_count(), 0);
        assert_eq!(d[0], Decision::Rejected(RejectReason::NoGpuFit));
        c.step(&[]); // hour 2: still down
        // Hour 3: repaired before the batch — placements resume.
        let d = c.step(&[vm(3, Profile::P7g40gb, 3 * HOUR + 20, 100 * HOUR)]);
        assert!(d[0].is_placed());
        // Interruption is not a rejection: the invariant stays exact.
        assert_eq!(c.rejections().iter().sum::<u64>(), c.requested() - c.accepted());
        let r = c.into_result(0.0);
        assert_eq!(r.interrupted, 1);
        // Availability: 4 sampled intervals, GPU offline in two of them.
        assert!((r.availability - 0.5).abs() < 1e-12);
    }

    #[test]
    fn queued_request_is_served_when_capacity_frees() {
        let mut c = core(1);
        c.set_admission_queue(QueueConfig { capacity: 4, ttl_hours: 10, preemption: false });
        let d = c.step(&[
            vm(1, Profile::P7g40gb, 10, HOUR + 5),
            vm(2, Profile::P7g40gb, 20, 100 * HOUR),
        ]);
        assert!(d[0].is_placed());
        assert_eq!(d[1], Decision::Rejected(RejectReason::Queued));
        assert_eq!(c.queue_len(), 1);
        c.admission_queue().verify().unwrap();
        // Hour 1: VM 1 departs, the queued request retries and lands.
        c.step(&[]);
        assert_eq!(c.queue_len(), 0);
        assert_eq!(c.accepted(), 2);
        assert_eq!(c.rejections().iter().sum::<u64>(), 0);
        let r = c.into_result(0.0);
        assert_eq!(r.queue_delays, vec![HOUR]);
    }

    #[test]
    fn queued_request_expires_after_ttl() {
        let mut c = core(1);
        c.set_admission_queue(QueueConfig { capacity: 4, ttl_hours: 2, preemption: false });
        c.step(&[
            vm(1, Profile::P7g40gb, 10, 100 * HOUR), // occupies forever
            vm(2, Profile::P7g40gb, 20, 100 * HOUR), // parks
        ]);
        c.step(&[]); // hour 1: retry fails, still parked
        assert_eq!(c.queue_len(), 1);
        c.step(&[]); // hour 2: TTL (2 h from t=1 h) lapses
        assert_eq!(c.queue_len(), 0);
        let r = c.into_result(0.0);
        assert_eq!(r.rejections[RejectReason::Expired.index()], 1);
        assert_eq!(r.rejections[RejectReason::Queued.index()], 0);
        assert_eq!(r.rejections.iter().sum::<u64>(), r.requested - r.accepted);
    }

    #[test]
    fn high_tier_arrival_preempts_low_tier_resident() {
        let mut c = core(1);
        c.set_admission_queue(QueueConfig { capacity: 4, ttl_hours: 10, preemption: true });
        let d = c.step(&[
            wvm(1, Profile::P7g40gb, 10, 100 * HOUR, 1.0),
            wvm(2, Profile::P7g40gb, 20, 100 * HOUR, 2.5),
        ]);
        assert!(d[0].is_placed());
        assert!(d[1].is_placed(), "high tier displaces the low-tier resident");
        assert_eq!(c.preempted(), 1);
        assert_eq!(c.accepted(), 1); // VM 1's acceptance was unwound
        assert_eq!(c.queue_len(), 1); // ...back into the queue
        assert_eq!(c.rejections()[RejectReason::Queued.index()], 1);
        assert_eq!(c.rejections().iter().sum::<u64>(), c.requested() - c.accepted());
        c.dc.check_integrity().unwrap();
        let r = c.into_result(0.0);
        assert_eq!(r.preempted, 1);
        // The still-parked victim flushes to Expired in the result.
        assert_eq!(r.rejections[RejectReason::Expired.index()], 1);
    }
}
