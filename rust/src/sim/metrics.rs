//! Metric collection for the §8 evaluation.

use crate::mig::profiles::ALL_PROFILES;
use crate::util::json::Json;
use crate::util::stats::auc;

/// One hourly sample (the points of Figs. 10 and 12).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    /// Simulation hour.
    pub hour: u64,
    /// Strict active-hardware rate (active PMs+GPUs / total).
    pub active_rate: f64,
    /// Cumulative acceptance rate up to this hour.
    pub acceptance_rate: f64,
    /// VMs resident at sampling time.
    pub resident: usize,
}

/// Full result of one simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub policy: String,
    pub samples: Vec<Sample>,
    /// Requests seen / accepted, total.
    pub requested: u64,
    pub accepted: u64,
    /// Per-profile `(requested, accepted)` in `ALL_PROFILES` order.
    pub per_profile: [(u64, u64); 6],
    /// Intra-GPU relocations performed (defragmentation).
    pub intra_migrations: u64,
    /// Inter-GPU migrations performed (consolidation).
    pub inter_migrations: u64,
    /// Wall-time of the run (for perf reporting), seconds.
    pub wall_seconds: f64,
}

impl SimResult {
    /// Overall acceptance rate at the end of the simulation (Fig. 10's
    /// terminal value).
    pub fn overall_acceptance(&self) -> f64 {
        if self.requested == 0 {
            0.0
        } else {
            self.accepted as f64 / self.requested as f64
        }
    }

    /// Mean of hourly active-hardware rates (Fig. 6's left axis).
    pub fn average_active_rate(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|s| s.active_rate).sum::<f64>() / self.samples.len() as f64
    }

    /// Table 6: area under the active-hardware-rate curve over simulation
    /// hours (trapezoidal). The paper's absolute values depend on its
    /// sampling units; the *normalized* column is what we compare.
    pub fn active_auc(&self) -> f64 {
        let pts: Vec<(f64, f64)> =
            self.samples.iter().map(|s| (s.hour as f64, 100.0 * s.active_rate)).collect();
        auc(&pts)
    }

    /// Per-profile acceptance rates (Figs. 7 and 11).
    pub fn per_profile_acceptance(&self) -> [f64; 6] {
        let mut out = [0.0; 6];
        for (i, (req, acc)) in self.per_profile.iter().enumerate() {
            out[i] = if *req == 0 { 0.0 } else { *acc as f64 / *req as f64 };
        }
        out
    }

    /// Mean of per-profile acceptance rates (Fig. 8's "average" line).
    pub fn average_profile_acceptance(&self) -> f64 {
        let rates = self.per_profile_acceptance();
        let used: Vec<f64> = self
            .per_profile
            .iter()
            .zip(rates)
            .filter(|((req, _), _)| *req > 0)
            .map(|(_, r)| r)
            .collect();
        if used.is_empty() {
            0.0
        } else {
            used.iter().sum::<f64>() / used.len() as f64
        }
    }

    /// Total migrations (§8.3.3).
    pub fn migrations(&self) -> u64 {
        self.intra_migrations + self.inter_migrations
    }

    /// Migrated share of accepted VMs (§8.3.3's "1%"). Upper bound: a VM
    /// may migrate more than once; the paper counts migration events.
    pub fn migration_share(&self) -> f64 {
        if self.accepted == 0 {
            0.0
        } else {
            self.migrations() as f64 / self.accepted as f64
        }
    }

    /// JSON export for the figure harness.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", self.policy.as_str().into()),
            ("requested", self.requested.into()),
            ("accepted", self.accepted.into()),
            ("overall_acceptance", self.overall_acceptance().into()),
            ("average_active_rate", self.average_active_rate().into()),
            ("active_auc", self.active_auc().into()),
            ("intra_migrations", self.intra_migrations.into()),
            ("inter_migrations", self.inter_migrations.into()),
            (
                "per_profile",
                Json::Obj(
                    ALL_PROFILES
                        .iter()
                        .enumerate()
                        .map(|(i, p)| {
                            (
                                p.name().to_string(),
                                Json::obj(vec![
                                    ("requested", self.per_profile[i].0.into()),
                                    ("accepted", self.per_profile[i].1.into()),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
            (
                "samples",
                Json::arr(
                    self.samples
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("hour", s.hour.into()),
                                ("active_rate", s.active_rate.into()),
                                ("acceptance_rate", s.acceptance_rate.into()),
                                ("resident", s.resident.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result() -> SimResult {
        SimResult {
            policy: "test".into(),
            samples: vec![
                Sample { hour: 0, active_rate: 0.0, acceptance_rate: 1.0, resident: 0 },
                Sample { hour: 1, active_rate: 0.5, acceptance_rate: 0.8, resident: 5 },
                Sample { hour: 2, active_rate: 1.0, acceptance_rate: 0.6, resident: 9 },
            ],
            requested: 10,
            accepted: 6,
            per_profile: [(2, 1), (0, 0), (4, 3), (2, 1), (1, 1), (1, 0)],
            intra_migrations: 2,
            inter_migrations: 1,
            wall_seconds: 0.1,
        }
    }

    #[test]
    fn rates() {
        let r = result();
        assert!((r.overall_acceptance() - 0.6).abs() < 1e-12);
        assert!((r.average_active_rate() - 0.5).abs() < 1e-12);
        assert_eq!(r.migrations(), 3);
        assert!((r.migration_share() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn per_profile_rates_skip_unrequested() {
        let r = result();
        let rates = r.per_profile_acceptance();
        assert_eq!(rates[1], 0.0);
        assert!((rates[2] - 0.75).abs() < 1e-12);
        // Average over the 5 requested profiles only.
        let expected = (0.5 + 0.75 + 0.5 + 1.0 + 0.0) / 5.0;
        assert!((r.average_profile_acceptance() - expected).abs() < 1e-12);
    }

    #[test]
    fn auc_trapezoid() {
        let r = result();
        // (0+50)/2 + (50+100)/2 = 100.
        assert!((r.active_auc() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrips() {
        let j = result().to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.get("accepted").unwrap().as_f64(), Some(6.0));
        assert_eq!(parsed.get("samples").unwrap().as_arr().unwrap().len(), 3);
    }
}
