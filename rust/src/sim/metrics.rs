//! Metric collection for the §8 evaluation.

use crate::mig::{GpuModel, ProfileKey, ALL_MODELS, NUM_MODELS, NUM_PROFILE_KEYS};
use crate::policies::{MigrationEvent, MigrationKind, RejectCounts, RejectReason};
use crate::util::json::Json;
use crate::util::stats::auc;

/// The crate-wide empty-denominator convention: with zero requests the
/// acceptance rate is **1.0** — vacuously perfect, since nothing was
/// refused. Shared by [`Sample`], [`SimResult::overall_acceptance`] and
/// the coordinator's stats so offline and online reports agree on an
/// idle system.
pub fn acceptance_rate(accepted: u64, requested: u64) -> f64 {
    if requested == 0 {
        1.0
    } else {
        accepted as f64 / requested as f64
    }
}

/// One hourly sample (the points of Figs. 10 and 12).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    /// Simulation hour.
    pub hour: u64,
    /// Strict active-hardware rate (active PMs+GPUs / total).
    pub active_rate: f64,
    /// Cumulative acceptance rate up to this hour.
    pub acceptance_rate: f64,
    /// VMs resident at sampling time.
    pub resident: usize,
}

/// Full result of one run — produced identically by the offline
/// simulator and the online coordinator (both drive the shared
/// [`crate::sim::EventCore`]).
#[derive(Debug, Clone)]
pub struct SimResult {
    pub policy: String,
    pub samples: Vec<Sample>,
    /// Requests seen / accepted, total.
    pub requested: u64,
    pub accepted: u64,
    /// Per-profile `(requested, accepted)` indexed by the dense
    /// cross-model [`ProfileKey::dense`] key. The first six slots are
    /// the A100-40 profiles in historical `ALL_PROFILES` order, so
    /// A100-only runs carry the pre-catalog layout with a zero tail.
    pub per_profile: [(u64, u64); NUM_PROFILE_KEYS],
    /// Rejections per [`RejectReason`] (indexed by `RejectReason::index`);
    /// sums to `requested - accepted`.
    pub rejections: RejectCounts,
    /// Every migration performed, in order (defragmentation relocations
    /// and consolidation moves).
    pub migration_events: Vec<MigrationEvent>,
    /// Fleet composition: GPU count per model (`GpuModel as usize`).
    pub gpus_by_model: [usize; NUM_MODELS],
    /// Cumulative per-model `(active, total)` GPU-interval counts across
    /// all samples — the per-model active-hardware breakdown.
    pub gpu_activity: [(u64, u64); NUM_MODELS],
    /// VMs evicted by hardware failures (terminal; not rejections and
    /// not subtracted from `accepted`).
    pub interrupted: u64,
    /// VMs preempted back into the admission queue by high-tier
    /// arrivals (their acceptance was unwound into a `Queued` count).
    pub preempted: u64,
    /// Queueing delay (seconds) of every request served from the
    /// admission queue, in service order.
    pub queue_delays: Vec<u64>,
    /// Mean per-interval fraction of schedulable GPUs (1.0 on a
    /// fault-free run or with zero sampled intervals).
    pub availability: f64,
    /// Optimality-gap samples (percent), one per metered interval —
    /// produced only when the run enables gap checking
    /// ([`crate::ilp::online::GapMeter`]); empty otherwise.
    pub gap_samples: Vec<f64>,
    /// Wall-time of the run (for perf reporting), seconds.
    pub wall_seconds: f64,
}

impl SimResult {
    /// Overall acceptance rate at the end of the simulation (Fig. 10's
    /// terminal value). Uses the crate-wide [`acceptance_rate`]
    /// convention (1.0 with zero requests).
    pub fn overall_acceptance(&self) -> f64 {
        acceptance_rate(self.accepted, self.requested)
    }

    /// Field-by-field equality of every *simulation outcome* — all
    /// fields except `wall_seconds`, which measures the host machine,
    /// not the simulated system. This is the crash-recovery determinism
    /// lock: a run resumed from a snapshot must `same_outcome` the
    /// uninterrupted run bit-for-bit (`f64`s compare exactly — both
    /// runs execute the identical operation sequence).
    pub fn same_outcome(&self, other: &SimResult) -> bool {
        self.policy == other.policy
            && self.samples == other.samples
            && self.requested == other.requested
            && self.accepted == other.accepted
            && self.per_profile == other.per_profile
            && self.rejections == other.rejections
            && self.migration_events == other.migration_events
            && self.gpus_by_model == other.gpus_by_model
            && self.gpu_activity == other.gpu_activity
            && self.interrupted == other.interrupted
            && self.preempted == other.preempted
            && self.queue_delays == other.queue_delays
            && self.availability == other.availability
            && self.gap_samples == other.gap_samples
    }

    /// Rejections attributed to one reason.
    pub fn rejected(&self, reason: RejectReason) -> u64 {
        self.rejections[reason.index()]
    }

    /// Mean of hourly active-hardware rates (Fig. 6's left axis).
    pub fn average_active_rate(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|s| s.active_rate).sum::<f64>() / self.samples.len() as f64
    }

    /// Table 6: area under the active-hardware-rate curve over simulation
    /// hours (trapezoidal). The paper's absolute values depend on its
    /// sampling units; the *normalized* column is what we compare.
    pub fn active_auc(&self) -> f64 {
        let pts: Vec<(f64, f64)> =
            self.samples.iter().map(|s| (s.hour as f64, 100.0 * s.active_rate)).collect();
        auc(&pts)
    }

    /// Per-profile acceptance rates (Figs. 7 and 11), by dense key.
    /// Profiles with zero requests report 0.0 here and are excluded from
    /// averages — the figures never plot an unrequested profile.
    pub fn per_profile_acceptance(&self) -> [f64; NUM_PROFILE_KEYS] {
        let mut out = [0.0; NUM_PROFILE_KEYS];
        for (i, (req, acc)) in self.per_profile.iter().enumerate() {
            out[i] = if *req == 0 { 0.0 } else { *acc as f64 / *req as f64 };
        }
        out
    }

    /// Mean of per-profile acceptance rates (Fig. 8's "average" line).
    pub fn average_profile_acceptance(&self) -> f64 {
        let rates = self.per_profile_acceptance();
        let used: Vec<f64> = self
            .per_profile
            .iter()
            .zip(rates)
            .filter(|((req, _), _)| *req > 0)
            .map(|(_, r)| r)
            .collect();
        if used.is_empty() {
            0.0
        } else {
            used.iter().sum::<f64>() / used.len() as f64
        }
    }

    /// Per-model `(requested, accepted)` — `per_profile` folded over each
    /// model's dense range.
    pub fn per_model_requests(&self) -> [(u64, u64); NUM_MODELS] {
        let mut out = [(0u64, 0u64); NUM_MODELS];
        for (d, (req, acc)) in self.per_profile.iter().enumerate() {
            let m = ProfileKey::from_dense(d).model() as usize;
            out[m].0 += req;
            out[m].1 += acc;
        }
        out
    }

    /// Mean active-GPU rate of one model across the run's samples
    /// (0.0 when the fleet has no GPUs of the model).
    pub fn model_active_rate(&self, model: GpuModel) -> f64 {
        let (active, total) = self.gpu_activity[model as usize];
        if total == 0 {
            0.0
        } else {
            active as f64 / total as f64
        }
    }

    /// Models present in the fleet, in catalog order.
    pub fn fleet_models(&self) -> Vec<GpuModel> {
        ALL_MODELS.into_iter().filter(|&m| self.gpus_by_model[m as usize] > 0).collect()
    }

    /// Intra-GPU relocations performed (defragmentation).
    pub fn intra_migrations(&self) -> u64 {
        self.migration_events.iter().filter(|e| e.kind == MigrationKind::Intra).count() as u64
    }

    /// Inter-GPU migrations performed (consolidation).
    pub fn inter_migrations(&self) -> u64 {
        self.migration_events.iter().filter(|e| e.kind == MigrationKind::Inter).count() as u64
    }

    /// Total migrations (§8.3.3).
    pub fn migrations(&self) -> u64 {
        self.migration_events.len() as u64
    }

    /// Migrated share of accepted VMs (§8.3.3's "1%"). Upper bound: a VM
    /// may migrate more than once; the paper counts migration events.
    pub fn migration_share(&self) -> f64 {
        if self.accepted == 0 {
            0.0
        } else {
            self.migrations() as f64 / self.accepted as f64
        }
    }

    /// Distinct VMs that migrated at least once.
    pub fn migrated_vms(&self) -> u64 {
        let mut seen: Vec<_> = self.migration_events.iter().map(|e| e.vm).collect();
        seen.sort_unstable();
        seen.dedup();
        seen.len() as u64
    }

    /// Share of accepted VMs that migrated at least once — the paper's
    /// §8.3.3 headline ("about 1% of MIG-enabled VMs were migrated"),
    /// counting each VM once however often it moved.
    pub fn migrated_vm_share(&self) -> f64 {
        if self.accepted == 0 {
            0.0
        } else {
            self.migrated_vms() as f64 / self.accepted as f64
        }
    }

    /// Memory blocks moved by migrations of one kind.
    pub fn migration_blocks(&self, kind: MigrationKind) -> u64 {
        self.migration_events
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| e.blocks as u64)
            .sum()
    }

    /// Cumulative block-weighted migration cost of one kind (Table 2's
    /// `IntraMigrate`/`InterMigrate` overheads: blocks moved × the
    /// kind's per-block weight).
    pub fn migration_cost(&self, kind: MigrationKind) -> u64 {
        self.migration_events.iter().filter(|e| e.kind == kind).map(|e| e.cost()).sum()
    }

    /// Total block-weighted migration cost across both kinds (the third
    /// objective's overhead term).
    pub fn total_migration_cost(&self) -> u64 {
        self.migration_events.iter().map(|e| e.cost()).sum()
    }

    /// Requests served from the admission queue.
    pub fn served_from_queue(&self) -> u64 {
        self.queue_delays.len() as u64
    }

    /// Queue-delay percentile in seconds (nearest-rank over the sorted
    /// samples); 0 when nothing was served from the queue.
    pub fn queue_delay_percentile(&self, p: f64) -> u64 {
        if self.queue_delays.is_empty() {
            return 0;
        }
        let mut sorted = self.queue_delays.clone();
        sorted.sort_unstable();
        let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
        sorted[rank.clamp(1, sorted.len()) - 1]
    }

    /// Median queue delay, seconds.
    pub fn queue_delay_p50(&self) -> u64 {
        self.queue_delay_percentile(50.0)
    }

    /// Tail queue delay, seconds.
    pub fn queue_delay_p99(&self) -> u64 {
        self.queue_delay_percentile(99.0)
    }

    /// Mean queue delay, seconds (0.0 with an unused queue).
    pub fn queue_delay_mean(&self) -> f64 {
        if self.queue_delays.is_empty() {
            return 0.0;
        }
        self.queue_delays.iter().sum::<u64>() as f64 / self.queue_delays.len() as f64
    }

    /// Mean optimality gap (percent) across the run's samples; `None`
    /// when the run collected none (gap metering disabled).
    pub fn gap_mean(&self) -> Option<f64> {
        if self.gap_samples.is_empty() {
            return None;
        }
        Some(self.gap_samples.iter().sum::<f64>() / self.gap_samples.len() as f64)
    }

    /// Worst sampled optimality gap (percent); `None` without samples.
    pub fn gap_max(&self) -> Option<f64> {
        self.gap_samples.iter().copied().fold(None, |acc, g| Some(acc.map_or(g, |a: f64| a.max(g))))
    }

    /// The profile keys a report should show for this result: the six
    /// A100-40 profiles (the paper's fixed column set) plus any other
    /// catalog key that saw requests, in dense order.
    pub fn reported_profiles(&self) -> Vec<ProfileKey> {
        ProfileKey::all()
            .filter(|k| k.model() == GpuModel::A100_40 || self.per_profile[k.dense()].0 > 0)
            .collect()
    }

    /// JSON export for the figure harness. A100-40 profiles keep their
    /// historical bare names; other models' entries are model-qualified
    /// (`"a30:2g.12gb"`) since profile names recur across models.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", self.policy.as_str().into()),
            ("requested", self.requested.into()),
            ("accepted", self.accepted.into()),
            ("overall_acceptance", self.overall_acceptance().into()),
            ("average_active_rate", self.average_active_rate().into()),
            ("active_auc", self.active_auc().into()),
            ("intra_migrations", self.intra_migrations().into()),
            ("inter_migrations", self.inter_migrations().into()),
            ("migrated_vms", self.migrated_vms().into()),
            ("migrated_vm_share", self.migrated_vm_share().into()),
            (
                "migration_cost",
                Json::obj(vec![
                    ("intra", self.migration_cost(MigrationKind::Intra).into()),
                    ("inter", self.migration_cost(MigrationKind::Inter).into()),
                    ("total", self.total_migration_cost().into()),
                ]),
            ),
            (
                "rejections",
                Json::Obj(
                    RejectReason::ALL
                        .iter()
                        .map(|r| (r.name().to_string(), self.rejected(*r).into()))
                        .collect(),
                ),
            ),
            (
                "ops",
                Json::obj(vec![
                    ("interrupted", self.interrupted.into()),
                    ("preempted", self.preempted.into()),
                    ("served_from_queue", self.served_from_queue().into()),
                    ("queue_delay_p50", self.queue_delay_p50().into()),
                    ("queue_delay_p99", self.queue_delay_p99().into()),
                    ("queue_delay_mean", self.queue_delay_mean().into()),
                    ("availability", self.availability.into()),
                ]),
            ),
            (
                "optimality_gap",
                Json::obj(vec![
                    ("samples", self.gap_samples.len().into()),
                    ("mean_pct", self.gap_mean().unwrap_or(0.0).into()),
                    ("max_pct", self.gap_max().unwrap_or(0.0).into()),
                ]),
            ),
            (
                "per_profile",
                Json::Obj(
                    self.reported_profiles()
                        .into_iter()
                        .map(|k| {
                            let (req, acc) = self.per_profile[k.dense()];
                            (
                                k.to_string(),
                                Json::obj(vec![
                                    ("requested", req.into()),
                                    ("accepted", acc.into()),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
            (
                "models",
                Json::Obj(
                    self.fleet_models()
                        .into_iter()
                        .map(|m| {
                            let (req, acc) = self.per_model_requests()[m as usize];
                            (
                                m.name().to_string(),
                                Json::obj(vec![
                                    ("gpus", self.gpus_by_model[m as usize].into()),
                                    ("requested", req.into()),
                                    ("accepted", acc.into()),
                                    (
                                        "acceptance",
                                        acceptance_rate(acc, req).into(),
                                    ),
                                    ("active_gpu_rate", self.model_active_rate(m).into()),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
            (
                "samples",
                Json::arr(
                    self.samples
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("hour", s.hour.into()),
                                ("active_rate", s.active_rate.into()),
                                ("acceptance_rate", s.acceptance_rate.into()),
                                ("resident", s.resident.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuRef;

    fn result() -> SimResult {
        let g0 = GpuRef { host: 0, gpu: 0 };
        let g1 = GpuRef { host: 0, gpu: 1 };
        let mut per_profile = [(0u64, 0u64); NUM_PROFILE_KEYS];
        per_profile[..6]
            .copy_from_slice(&[(2, 1), (0, 0), (4, 3), (2, 1), (1, 1), (1, 0)]);
        let mut gpus_by_model = [0usize; NUM_MODELS];
        gpus_by_model[GpuModel::A100_40 as usize] = 2;
        let mut gpu_activity = [(0u64, 0u64); NUM_MODELS];
        gpu_activity[GpuModel::A100_40 as usize] = (3, 6);
        SimResult {
            policy: "test".into(),
            samples: vec![
                Sample { hour: 0, active_rate: 0.0, acceptance_rate: 1.0, resident: 0 },
                Sample { hour: 1, active_rate: 0.5, acceptance_rate: 0.8, resident: 5 },
                Sample { hour: 2, active_rate: 1.0, acceptance_rate: 0.6, resident: 9 },
            ],
            requested: 10,
            accepted: 6,
            per_profile,
            rejections: [1, 0, 2, 1, 0, 0],
            migration_events: vec![
                MigrationEvent {
                    vm: 1,
                    from: g0,
                    to: g0,
                    kind: MigrationKind::Intra,
                    model: GpuModel::A100_40,
                    blocks: 1,
                },
                MigrationEvent {
                    vm: 2,
                    from: g0,
                    to: g0,
                    kind: MigrationKind::Intra,
                    model: GpuModel::A100_40,
                    blocks: 2,
                },
                MigrationEvent {
                    vm: 3,
                    from: g0,
                    to: g1,
                    kind: MigrationKind::Inter,
                    model: GpuModel::A100_40,
                    blocks: 4,
                },
            ],
            gpus_by_model,
            gpu_activity,
            interrupted: 0,
            preempted: 0,
            queue_delays: Vec::new(),
            availability: 1.0,
            gap_samples: Vec::new(),
            wall_seconds: 0.1,
        }
    }

    #[test]
    fn rates() {
        let r = result();
        assert!((r.overall_acceptance() - 0.6).abs() < 1e-12);
        assert!((r.average_active_rate() - 0.5).abs() < 1e-12);
        assert_eq!(r.intra_migrations(), 2);
        assert_eq!(r.inter_migrations(), 1);
        assert_eq!(r.migrations(), 3);
        assert!((r.migration_share() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn same_outcome_ignores_wall_clock_only() {
        let a = result();
        let mut b = result();
        b.wall_seconds = 99.0;
        assert!(a.same_outcome(&b), "wall_seconds must not affect outcome equality");

        let mut c = result();
        c.accepted += 1;
        assert!(!a.same_outcome(&c));

        let mut d = result();
        d.samples[1].active_rate += 1e-9;
        assert!(!a.same_outcome(&d));

        let mut e = result();
        e.migration_events.pop();
        assert!(!a.same_outcome(&e));
    }

    #[test]
    fn migration_cost_accounting() {
        let mut r = result();
        // Intra: 1 + 2 blocks × weight 1; inter: 4 blocks × weight 2.
        assert_eq!(r.migration_blocks(MigrationKind::Intra), 3);
        assert_eq!(r.migration_blocks(MigrationKind::Inter), 4);
        assert_eq!(r.migration_cost(MigrationKind::Intra), 3);
        assert_eq!(r.migration_cost(MigrationKind::Inter), 8);
        assert_eq!(r.total_migration_cost(), 11);
        // Three distinct VMs migrated of 6 accepted.
        assert_eq!(r.migrated_vms(), 3);
        assert!((r.migrated_vm_share() - 0.5).abs() < 1e-12);
        // A repeat move of VM 1 raises events/cost but not distinct VMs.
        let again = MigrationEvent { vm: 1, ..r.migration_events[0] };
        r.migration_events.push(again);
        assert_eq!(r.migrations(), 4);
        assert_eq!(r.migrated_vms(), 3);
        assert!(r.migration_share() > r.migrated_vm_share());
    }

    #[test]
    fn rejection_breakdown_sums_to_refused() {
        let r = result();
        assert_eq!(r.rejections.iter().sum::<u64>(), r.requested - r.accepted);
        assert_eq!(r.rejected(RejectReason::CpuExhausted), 1);
        assert_eq!(r.rejected(RejectReason::NoGpuFit), 2);
        assert_eq!(r.rejected(RejectReason::QuotaDenied), 1);
    }

    #[test]
    fn empty_denominator_convention_is_one() {
        let mut r = result();
        r.requested = 0;
        r.accepted = 0;
        assert!((r.overall_acceptance() - 1.0).abs() < 1e-12);
        assert!((acceptance_rate(0, 0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn per_profile_rates_skip_unrequested() {
        let r = result();
        let rates = r.per_profile_acceptance();
        assert_eq!(rates[1], 0.0);
        assert!((rates[2] - 0.75).abs() < 1e-12);
        // Average over the 5 requested profiles only.
        let expected = (0.5 + 0.75 + 0.5 + 1.0 + 0.0) / 5.0;
        assert!((r.average_profile_acceptance() - expected).abs() < 1e-12);
    }

    #[test]
    fn per_model_rollups() {
        let mut r = result();
        // Route one request stream through an A30 key too.
        let k = GpuModel::A30.profile(1);
        r.per_profile[k.dense()] = (3, 2);
        r.requested += 3;
        r.accepted += 2;
        r.gpus_by_model[GpuModel::A30 as usize] = 1;
        r.gpu_activity[GpuModel::A30 as usize] = (1, 3);
        let by_model = r.per_model_requests();
        assert_eq!(by_model[GpuModel::A100_40 as usize], (10, 6));
        assert_eq!(by_model[GpuModel::A30 as usize], (3, 2));
        assert_eq!(r.fleet_models(), vec![GpuModel::A100_40, GpuModel::A30]);
        assert!((r.model_active_rate(GpuModel::A100_40) - 0.5).abs() < 1e-12);
        assert!((r.model_active_rate(GpuModel::A30) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(r.model_active_rate(GpuModel::H100_80), 0.0);
        // Reported columns: the A100-40 six plus the requested A30 key.
        let cols = r.reported_profiles();
        assert_eq!(cols.len(), 7);
        assert!(cols.contains(&k));
    }

    #[test]
    fn auc_trapezoid() {
        let r = result();
        // (0+50)/2 + (50+100)/2 = 100.
        assert!((r.active_auc() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn queue_delay_percentiles() {
        let mut r = result();
        assert_eq!(r.queue_delay_p50(), 0);
        assert_eq!(r.queue_delay_mean(), 0.0);
        r.queue_delays = vec![400, 100, 200, 300];
        assert_eq!(r.served_from_queue(), 4);
        assert_eq!(r.queue_delay_p50(), 200);
        assert_eq!(r.queue_delay_p99(), 400);
        assert!((r.queue_delay_mean() - 250.0).abs() < 1e-12);
    }

    #[test]
    fn gap_sample_rollups() {
        let mut r = result();
        assert_eq!(r.gap_mean(), None, "no samples without gap metering");
        assert_eq!(r.gap_max(), None);
        r.gap_samples = vec![0.0, 3.0, 1.5];
        assert!((r.gap_mean().unwrap() - 1.5).abs() < 1e-12);
        assert!((r.gap_max().unwrap() - 3.0).abs() < 1e-12);
        let j = r.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string_compact()).unwrap();
        let gap = parsed.get("optimality_gap").unwrap();
        assert_eq!(gap.get("samples").unwrap().as_f64(), Some(3.0));
        assert_eq!(gap.get("max_pct").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn json_roundtrips() {
        let j = result().to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.get("accepted").unwrap().as_f64(), Some(6.0));
        assert_eq!(parsed.get("samples").unwrap().as_arr().unwrap().len(), 3);
        let rej = parsed.get("rejections").unwrap();
        assert_eq!(rej.get("no_gpu_fit").unwrap().as_f64(), Some(2.0));
        assert_eq!(rej.get("quota_denied").unwrap().as_f64(), Some(1.0));
        let cost = parsed.get("migration_cost").unwrap();
        assert_eq!(cost.get("intra").unwrap().as_f64(), Some(3.0));
        assert_eq!(cost.get("inter").unwrap().as_f64(), Some(8.0));
        assert_eq!(cost.get("total").unwrap().as_f64(), Some(11.0));
        assert_eq!(parsed.get("migrated_vms").unwrap().as_f64(), Some(3.0));
        // Historical bare profile keys survive for the A100-40.
        let pp = parsed.get("per_profile").unwrap();
        assert_eq!(pp.get("2g.10gb").unwrap().get("accepted").unwrap().as_f64(), Some(3.0));
        // Per-model rollup present for the fleet's models only.
        let models = parsed.get("models").unwrap();
        assert_eq!(models.get("a100-40").unwrap().get("gpus").unwrap().as_f64(), Some(2.0));
        assert!(models.get("a30").is_none());
        let ops = parsed.get("ops").unwrap();
        assert_eq!(ops.get("availability").unwrap().as_f64(), Some(1.0));
        assert_eq!(ops.get("interrupted").unwrap().as_f64(), Some(0.0));
        assert_eq!(rej.get("queued").unwrap().as_f64(), Some(0.0));
        assert_eq!(rej.get("expired").unwrap().as_f64(), Some(0.0));
    }
}
