//! Seeded fault/maintenance schedule generation (the `FaultInjector`).
//!
//! Failures are drawn up front, not online: the injector walks the
//! fleet in ascending host/GPU order, draws each device's alternating
//! exponential fail→repair renewal process from a dedicated PCG stream,
//! and emits one flat schedule sorted by time. The event core replays
//! the schedule at deterministic points of the interval loop, so runs
//! are byte-reproducible across thread counts and across machines —
//! and a configuration with every rate at zero draws *nothing*, leaving
//! the decision stream byte-identical to a fault-free build.

use crate::cluster::vm::{Time, HOUR};
use crate::cluster::{GpuRef, Host};
use crate::mig::NUM_MODELS;
use crate::util::rng::Rng;

/// Operational-model configuration: MTBF/MTTR per GPU model, host
/// fail/repair rates, and the maintenance-drain process. All rates
/// default to zero (disabled); hours are wall-clock simulation hours.
#[derive(Debug, Clone, PartialEq)]
pub struct OpsConfig {
    /// Mean time between failures per GPU model, hours; `0.0` disables
    /// failures for that model.
    pub gpu_mtbf_hours: [f64; NUM_MODELS],
    /// Mean time to repair a failed GPU, hours.
    pub gpu_mttr_hours: f64,
    /// Mean time between whole-host failures, hours; `0.0` disables.
    pub host_mtbf_hours: f64,
    /// Mean time to repair a failed host, hours.
    pub host_mttr_hours: f64,
    /// Maintenance drains per host per 1 000 hours; `0.0` disables.
    pub drain_rate: f64,
    /// Fixed drain duration, hours.
    pub drain_hours: f64,
    /// Ban a GPU (permanently offline) after this many failures;
    /// `0` never bans. Mirrors production schedulers that blocklist
    /// repeat-offender devices instead of endlessly recycling them.
    pub ban_after_failures: u32,
    /// Correlated-failure escalation probability: each host failure
    /// takes its whole failure domain (rack/pod) down with it with this
    /// probability. `0.0` (the default) draws nothing and leaves the
    /// schedule byte-identical to the uncorrelated model.
    pub blast_radius: f64,
    /// Failure-domain size in hosts (consecutive ids share a domain:
    /// `host / blast_hosts`). Values below 2 leave escalation inert —
    /// a one-host domain has nothing else to take down. The sharded
    /// runner defaults this to the shard size when unset.
    pub blast_hosts: u32,
    /// Schedule horizon in hours (events beyond it are not drawn).
    pub horizon_hours: u64,
    /// Seed of the injector's own RNG stream (independent of the
    /// policy RNG — see the module docs' determinism note).
    pub seed: u64,
}

impl Default for OpsConfig {
    fn default() -> Self {
        OpsConfig {
            gpu_mtbf_hours: [0.0; NUM_MODELS],
            gpu_mttr_hours: 4.0,
            host_mtbf_hours: 0.0,
            host_mttr_hours: 8.0,
            drain_rate: 0.0,
            drain_hours: 2.0,
            ban_after_failures: 0,
            blast_radius: 0.0,
            blast_hosts: 0,
            horizon_hours: 0,
            seed: 0,
        }
    }
}

impl OpsConfig {
    /// Uniform GPU MTBF across every model.
    pub fn with_gpu_mtbf(mut self, hours: f64) -> OpsConfig {
        self.gpu_mtbf_hours = [hours; NUM_MODELS];
        self
    }

    /// Does any process have a non-zero rate?
    pub fn enabled(&self) -> bool {
        self.gpu_mtbf_hours.iter().any(|&m| m > 0.0)
            || self.host_mtbf_hours > 0.0
            || self.drain_rate > 0.0
    }
}

/// One operational event, applied by the event core at its timestamp.
/// Fail events carry their repair time (`until`) so state queries can
/// answer "down until when" without scanning the rest of the schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpsEvent {
    /// A GPU goes down; residents are evicted (interrupted).
    GpuFail { gpu: GpuRef, until: Time },
    /// A failed GPU comes back (or is banned, if it has failed
    /// [`OpsConfig::ban_after_failures`] times).
    GpuRepair { gpu: GpuRef },
    /// A whole host goes down; all residents are evicted.
    HostFail { host: u32, until: Time },
    /// A failed host comes back.
    HostRepair { host: u32 },
    /// Maintenance drain begins: the host stops accepting placements
    /// and its residents are evacuated (all-or-nothing) if the rest of
    /// the fleet can hold them.
    DrainStart { host: u32, until: Time },
    /// Maintenance drain ends; the host is schedulable again.
    DrainDone { host: u32 },
    /// The engine repaired corrupted derived state at a maintenance
    /// tick (see `recover::OnCorruption`): `host` is the quarantined
    /// host, or [`STATE_REPAIR_NO_HOST`] when the rebuild was
    /// cluster-wide. Never part of a generated schedule — it is logged
    /// by the event core, not replayed by it.
    StateRepair { host: u32 },
}

/// Sentinel host id of an [`OpsEvent::StateRepair`] that was not
/// attributable to a single host.
pub const STATE_REPAIR_NO_HOST: u32 = u32::MAX;

impl OpsEvent {
    /// Serialize for crash-safe snapshots ([`crate::recover`]).
    pub(crate) fn encode(&self, e: &mut crate::util::codec::Enc) {
        match *self {
            OpsEvent::GpuFail { gpu, until } => {
                e.u8(0);
                e.u32(gpu.host);
                e.u8(gpu.gpu);
                e.u64(until);
            }
            OpsEvent::GpuRepair { gpu } => {
                e.u8(1);
                e.u32(gpu.host);
                e.u8(gpu.gpu);
            }
            OpsEvent::HostFail { host, until } => {
                e.u8(2);
                e.u32(host);
                e.u64(until);
            }
            OpsEvent::HostRepair { host } => {
                e.u8(3);
                e.u32(host);
            }
            OpsEvent::DrainStart { host, until } => {
                e.u8(4);
                e.u32(host);
                e.u64(until);
            }
            OpsEvent::DrainDone { host } => {
                e.u8(5);
                e.u32(host);
            }
            OpsEvent::StateRepair { host } => {
                e.u8(6);
                e.u32(host);
            }
        }
    }

    /// Inverse of [`OpsEvent::encode`].
    pub(crate) fn decode(d: &mut crate::util::codec::Dec) -> Result<OpsEvent, String> {
        Ok(match d.u8()? {
            0 => OpsEvent::GpuFail {
                gpu: GpuRef { host: d.u32()?, gpu: d.u8()? },
                until: d.u64()?,
            },
            1 => OpsEvent::GpuRepair { gpu: GpuRef { host: d.u32()?, gpu: d.u8()? } },
            2 => OpsEvent::HostFail { host: d.u32()?, until: d.u64()? },
            3 => OpsEvent::HostRepair { host: d.u32()? },
            4 => OpsEvent::DrainStart { host: d.u32()?, until: d.u64()? },
            5 => OpsEvent::DrainDone { host: d.u32()? },
            6 => OpsEvent::StateRepair { host: d.u32()? },
            t => return Err(format!("malformed ops-event tag {t}")),
        })
    }
}

/// Draw the full fault/maintenance schedule for `hosts` under `cfg`,
/// sorted ascending by time (ties keep generation order: hosts before
/// their GPUs, ascending ids — the sort is stable). Returns an empty
/// schedule when nothing is [`enabled`](OpsConfig::enabled).
pub fn generate_schedule(cfg: &OpsConfig, hosts: &[Host]) -> Vec<(Time, OpsEvent)> {
    if !cfg.enabled() || cfg.horizon_hours == 0 {
        return Vec::new();
    }
    let mut rng = Rng::new(cfg.seed ^ 0x6f70_735f_6772_6d75); // "ops_grmu"
    let horizon = cfg.horizon_hours * HOUR;
    let mut out: Vec<(Time, OpsEvent)> = Vec::new();

    for h in hosts {
        // Host fail/repair renewal process.
        if cfg.host_mtbf_hours > 0.0 {
            renewal(&mut rng, cfg.host_mtbf_hours, cfg.host_mttr_hours, horizon, |t, until| {
                out.push((t, OpsEvent::HostFail { host: h.id, until }));
                if until < horizon {
                    out.push((until, OpsEvent::HostRepair { host: h.id }));
                }
            });
        }
        // Maintenance drains: exponential inter-drain gaps, fixed length.
        if cfg.drain_rate > 0.0 {
            let mean_gap_hours = 1_000.0 / cfg.drain_rate;
            renewal_fixed(&mut rng, mean_gap_hours, cfg.drain_hours, horizon, |t, until| {
                out.push((t, OpsEvent::DrainStart { host: h.id, until }));
                if until < horizon {
                    out.push((until, OpsEvent::DrainDone { host: h.id }));
                }
            });
        }
        // Per-GPU fail/repair renewal processes.
        for (g, gpu) in h.gpus().iter().enumerate() {
            let mtbf = cfg.gpu_mtbf_hours[gpu.model() as usize];
            if mtbf <= 0.0 {
                continue;
            }
            let r = GpuRef { host: h.id, gpu: g as u8 };
            renewal(&mut rng, mtbf, cfg.gpu_mttr_hours, horizon, |t, until| {
                out.push((t, OpsEvent::GpuFail { gpu: r, until }));
                if until < horizon {
                    out.push((until, OpsEvent::GpuRepair { gpu: r }));
                }
            });
        }
    }
    // Correlated-failure escalation (blast radius): a host failure may
    // take its whole failure domain down with it. Drawn in a *second*
    // pass over the primary host failures (generation order, i.e.
    // ascending host id then time) from a dedicated RNG stream, so a
    // zero rate changes no draw of the renewal streams above and the
    // schedule stays byte-identical. Escalated failures do not escalate
    // further, and co-failed hosts reuse the primary's outage window —
    // the whole rack loses power together and comes back together.
    if cfg.blast_radius > 0.0 && cfg.blast_hosts >= 2 {
        let mut blast_rng = Rng::new(cfg.seed ^ 0x626c_6173_745f_6772); // "blast_gr"
        let primaries: Vec<(Time, u32, Time)> = out
            .iter()
            .filter_map(|&(t, ev)| match ev {
                OpsEvent::HostFail { host, until } => Some((t, host, until)),
                _ => None,
            })
            .collect();
        let host_ids: Vec<u32> = hosts.iter().map(|h| h.id).collect();
        for (t, host, until) in primaries {
            if blast_rng.f64() >= cfg.blast_radius {
                continue;
            }
            let domain = host / cfg.blast_hosts;
            for &other in &host_ids {
                if other == host || other / cfg.blast_hosts != domain {
                    continue;
                }
                out.push((t, OpsEvent::HostFail { host: other, until }));
                if until < horizon {
                    out.push((until, OpsEvent::HostRepair { host: other }));
                }
            }
        }
    }
    // Stable by-time sort: same-resource events were pushed in time
    // order, so their relative order (fail before its repair) survives.
    // Blast co-failures land *after* any primary event sharing their
    // timestamp — the event core's health guards make overlapping
    // fail/repair windows commute.
    out.sort_by_key(|&(t, _)| t);
    out
}

/// Alternating exponential up/down renewal process over `[0, horizon)`.
/// Repair draws are floored at one second so a fail and its repair never
/// collapse onto the same timestamp.
fn renewal(
    rng: &mut Rng,
    up_mean_hours: f64,
    down_mean_hours: f64,
    horizon: Time,
    mut emit: impl FnMut(Time, Time),
) {
    let mut t = 0.0f64;
    loop {
        t += rng.exponential(1.0 / (up_mean_hours * HOUR as f64));
        let fail = t as Time;
        if fail >= horizon {
            return;
        }
        let down = rng.exponential(1.0 / (down_mean_hours.max(1e-9) * HOUR as f64)).max(1.0);
        let repair = fail + down as Time + 1;
        emit(fail, repair);
        t = repair as f64;
    }
}

/// Renewal process with exponential gaps and a fixed down-time (drains).
fn renewal_fixed(
    rng: &mut Rng,
    gap_mean_hours: f64,
    down_hours: f64,
    horizon: Time,
    mut emit: impl FnMut(Time, Time),
) {
    let down = ((down_hours * HOUR as f64) as Time).max(1);
    let mut t = 0.0f64;
    loop {
        t += rng.exponential(1.0 / (gap_mean_hours * HOUR as f64));
        let start = t as Time;
        if start >= horizon {
            return;
        }
        emit(start, start + down);
        t = (start + down) as f64;
    }
}

/// The configured injector: owns the schedule and a replay cursor. The
/// event core pulls due events each interval via
/// [`FaultInjector::pop_due`].
#[derive(Debug, Clone, Default)]
pub struct FaultInjector {
    schedule: Vec<(Time, OpsEvent)>,
    cursor: usize,
    /// Per-GPU failure tally for the ban policy, keyed by (host, gpu).
    failures: std::collections::HashMap<(u32, u8), u32>,
    ban_after: u32,
}

impl FaultInjector {
    /// Injector over a pre-generated schedule.
    pub fn new(schedule: Vec<(Time, OpsEvent)>, ban_after_failures: u32) -> FaultInjector {
        debug_assert!(schedule.windows(2).all(|w| w[0].0 <= w[1].0), "schedule sorted");
        FaultInjector {
            schedule,
            cursor: 0,
            failures: std::collections::HashMap::new(),
            ban_after: ban_after_failures,
        }
    }

    /// Generate and wrap the schedule for `hosts` under `cfg`.
    pub fn from_config(cfg: &OpsConfig, hosts: &[Host]) -> FaultInjector {
        FaultInjector::new(generate_schedule(cfg, hosts), cfg.ban_after_failures)
    }

    /// Decompose into `(schedule, ban_after_failures)`. The sharded
    /// runner generates one *global* schedule (so faults are identical
    /// at every shard count), then splits it per owning shard and
    /// re-wraps each part. Must be called before replay starts.
    pub fn into_parts(self) -> (Vec<(Time, OpsEvent)>, u32) {
        debug_assert_eq!(self.cursor, 0, "split before replay");
        (self.schedule, self.ban_after)
    }

    /// Mid-run snapshot of the replay state for the crash-safe
    /// persistence layer: `(schedule, cursor, failure tally, ban
    /// threshold)`. Unlike [`FaultInjector::into_parts`] this is legal
    /// at any point of the replay — the cursor and the per-GPU failure
    /// tally are exactly what a resumed run must not lose.
    pub fn snapshot_parts(&self) -> (&[(Time, OpsEvent)], usize, Vec<((u32, u8), u32)>, u32) {
        let mut failures: Vec<((u32, u8), u32)> = self.failures.iter().map(|(&k, &v)| (k, v)).collect();
        failures.sort_unstable();
        (&self.schedule, self.cursor, failures, self.ban_after)
    }

    /// Rebuild an injector at an exact replay position captured by
    /// [`FaultInjector::snapshot_parts`].
    pub fn from_snapshot(
        schedule: Vec<(Time, OpsEvent)>,
        cursor: usize,
        failures: Vec<((u32, u8), u32)>,
        ban_after: u32,
    ) -> FaultInjector {
        FaultInjector {
            schedule,
            cursor,
            failures: failures.into_iter().collect(),
            ban_after,
        }
    }

    /// Any events left to replay?
    pub fn is_exhausted(&self) -> bool {
        self.cursor >= self.schedule.len()
    }

    /// Total scheduled events (for reporting).
    pub fn len(&self) -> usize {
        self.schedule.len()
    }

    pub fn is_empty(&self) -> bool {
        self.schedule.is_empty()
    }

    /// Next event with timestamp ≤ `now`, advancing the cursor.
    pub fn pop_due(&mut self, now: Time) -> Option<(Time, OpsEvent)> {
        let &(t, ev) = self.schedule.get(self.cursor)?;
        if t > now {
            return None;
        }
        self.cursor += 1;
        Some((t, ev))
    }

    /// Record one failure of `gpu`; returns `true` if the device has
    /// now failed often enough to be banned instead of repaired.
    pub fn record_failure(&mut self, gpu: GpuRef) -> bool {
        let n = self.failures.entry((gpu.host, gpu.gpu)).or_insert(0);
        *n += 1;
        self.ban_after > 0 && *n >= self.ban_after
    }

    /// Has `gpu` accumulated enough recorded failures to be banned?
    pub fn is_banned(&self, gpu: GpuRef) -> bool {
        self.ban_after > 0
            && self
                .failures
                .get(&(gpu.host, gpu.gpu))
                .map_or(false, |&n| n >= self.ban_after)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;

    fn fleet() -> Vec<Host> {
        (0..4).map(|i| Host::new(i, 64, 256, 2)).collect()
    }

    #[test]
    fn disabled_config_draws_nothing() {
        let cfg = OpsConfig { horizon_hours: 100, ..OpsConfig::default() };
        assert!(!cfg.enabled());
        assert!(generate_schedule(&cfg, &fleet()).is_empty());
    }

    #[test]
    fn schedule_is_sorted_and_reproducible() {
        let cfg = OpsConfig {
            host_mtbf_hours: 50.0,
            drain_rate: 5.0,
            horizon_hours: 500,
            seed: 7,
            ..OpsConfig::default()
        }
        .with_gpu_mtbf(80.0);
        let a = generate_schedule(&cfg, &fleet());
        let b = generate_schedule(&cfg, &fleet());
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert!(a.windows(2).all(|w| w[0].0 <= w[1].0));
        // Every fail's `until` strictly exceeds its timestamp.
        for &(t, ev) in &a {
            match ev {
                OpsEvent::GpuFail { until, .. }
                | OpsEvent::HostFail { until, .. }
                | OpsEvent::DrainStart { until, .. } => assert!(until > t),
                _ => {}
            }
        }
    }

    #[test]
    fn repairs_follow_their_failures() {
        let cfg = OpsConfig {
            gpu_mttr_hours: 2.0,
            horizon_hours: 2_000,
            seed: 11,
            ..OpsConfig::default()
        }
        .with_gpu_mtbf(100.0);
        let sched = generate_schedule(&cfg, &fleet());
        let mut down: std::collections::HashSet<(u32, u8)> = Default::default();
        for &(_, ev) in &sched {
            match ev {
                OpsEvent::GpuFail { gpu, .. } => {
                    assert!(down.insert((gpu.host, gpu.gpu)), "double fail while down");
                }
                OpsEvent::GpuRepair { gpu } => {
                    assert!(down.remove(&(gpu.host, gpu.gpu)), "repair without fail");
                }
                _ => {}
            }
        }
    }

    #[test]
    fn zero_blast_radius_is_byte_identical() {
        let base = OpsConfig {
            host_mtbf_hours: 50.0,
            drain_rate: 5.0,
            horizon_hours: 500,
            seed: 7,
            ..OpsConfig::default()
        }
        .with_gpu_mtbf(80.0);
        let with_field = OpsConfig { blast_radius: 0.0, blast_hosts: 2, ..base.clone() };
        assert_eq!(generate_schedule(&base, &fleet()), generate_schedule(&with_field, &fleet()));
        // An escalation probability without a multi-host domain is inert
        // too: there is nothing else in the domain to take down.
        let no_domain = OpsConfig { blast_radius: 0.5, blast_hosts: 1, ..base.clone() };
        assert_eq!(generate_schedule(&base, &fleet()), generate_schedule(&no_domain, &fleet()));
    }

    #[test]
    fn blast_escalation_cofails_the_domain() {
        let cfg = OpsConfig {
            host_mtbf_hours: 200.0,
            horizon_hours: 2_000,
            seed: 13,
            blast_radius: 1.0, // every host failure escalates
            blast_hosts: 2,    // domains: {0,1}, {2,3}
            ..OpsConfig::default()
        };
        let sched = generate_schedule(&cfg, &fleet());
        assert_eq!(sched, generate_schedule(&cfg, &fleet()), "deterministic");
        assert!(sched.windows(2).all(|w| w[0].0 <= w[1].0), "sorted");
        // Every primary failure has a same-timestamp co-failure of its
        // domain partner with the same outage window.
        let fails: Vec<(Time, u32, Time)> = sched
            .iter()
            .filter_map(|&(t, ev)| match ev {
                OpsEvent::HostFail { host, until } => Some((t, host, until)),
                _ => None,
            })
            .collect();
        assert!(!fails.is_empty());
        for &(t, host, until) in &fails {
            let partner = host ^ 1; // the other host of a 2-wide domain
            assert!(
                fails.iter().any(|&(t2, h2, u2)| t2 == t && h2 == partner && u2 == until),
                "host {host} failing at {t} must co-fail {partner}"
            );
        }
        // With p = 1 every failure is mirrored: the count doubles
        // exactly relative to the uncorrelated schedule.
        let solo = OpsConfig { blast_radius: 0.0, ..cfg.clone() };
        let solo_fails = generate_schedule(&solo, &fleet())
            .iter()
            .filter(|(_, ev)| matches!(ev, OpsEvent::HostFail { .. }))
            .count();
        assert_eq!(fails.len(), 2 * solo_fails);
    }

    #[test]
    fn injector_into_parts_round_trips() {
        let sched = vec![
            (10, OpsEvent::HostFail { host: 1, until: 20 }),
            (20, OpsEvent::HostRepair { host: 1 }),
        ];
        let inj = FaultInjector::new(sched.clone(), 3);
        let (parts, ban) = inj.into_parts();
        assert_eq!(parts, sched);
        assert_eq!(ban, 3);
    }

    #[test]
    fn injector_snapshot_parts_round_trips_mid_replay() {
        let r = GpuRef { host: 0, gpu: 1 };
        let sched = vec![
            (10, OpsEvent::GpuFail { gpu: r, until: 20 }),
            (20, OpsEvent::GpuRepair { gpu: r }),
            (40, OpsEvent::HostFail { host: 2, until: 50 }),
        ];
        let mut inj = FaultInjector::new(sched, 2);
        let _ = inj.pop_due(15);
        inj.record_failure(r);
        let (schedule, cursor, failures, ban) = inj.snapshot_parts();
        let mut twin = FaultInjector::from_snapshot(schedule.to_vec(), cursor, failures, ban);
        assert_eq!(twin.pop_due(25), inj.pop_due(25));
        assert_eq!(twin.pop_due(60), inj.pop_due(60));
        assert!(twin.record_failure(r), "restored tally keeps the first strike");
        assert_eq!(twin.is_exhausted(), inj.is_exhausted());
    }

    #[test]
    fn injector_cursor_and_ban_tally() {
        let sched = vec![
            (10, OpsEvent::GpuFail { gpu: GpuRef { host: 0, gpu: 0 }, until: 20 }),
            (20, OpsEvent::GpuRepair { gpu: GpuRef { host: 0, gpu: 0 } }),
        ];
        let mut inj = FaultInjector::new(sched, 2);
        assert!(inj.pop_due(5).is_none());
        assert!(matches!(inj.pop_due(15), Some((10, OpsEvent::GpuFail { .. }))));
        assert!(inj.pop_due(15).is_none());
        assert!(matches!(inj.pop_due(30), Some((20, OpsEvent::GpuRepair { .. }))));
        assert!(inj.is_exhausted());
        let r = GpuRef { host: 0, gpu: 0 };
        assert!(!inj.record_failure(r));
        assert!(!inj.is_banned(r));
        assert!(inj.record_failure(r)); // second strike → ban
        assert!(inj.is_banned(r));
        assert!(!inj.is_banned(GpuRef { host: 1, gpu: 0 }));
    }
}
