//! All-or-nothing host evacuation planning for maintenance drains.
//!
//! A drain must either move *every* resident off the host or leave the
//! host untouched — a half-evacuated machine helps no one (the
//! maintenance still cannot start) and costs real migrations. The
//! planner therefore builds the whole relocation as one
//! [`MigrationPlan`] over a [`PlanView`] overlay and returns `None` the
//! moment any resident has no feasible destination; nothing has touched
//! the live cluster at that point. The transactional
//! `DataCenter::apply_plan` then lands the plan atomically (with
//! rollback on a racing change), exactly like every other planner.

use crate::cluster::{DataCenter, GpuRef};
use crate::migrate::{MigrationPlan, PlanView};
use crate::mig::mock_assign;

/// Plan the evacuation of every VM resident on `host`, first-fit over
/// ascending [`GpuRef`] destinations (the `globalIndex` order shared
/// with the placement policies — deterministic and
/// occupancy-overlay-aware). Returns `None` if any resident cannot be
/// re-homed, or an empty plan if the host holds no VMs.
pub fn plan_evacuation(dc: &DataCenter, host: u32) -> Option<MigrationPlan> {
    let mut plan = MigrationPlan::new();
    let mut view = PlanView::new(dc);
    for vm in dc.vms_on_host(host) {
        let loc = dc.location(vm)?;
        let (cpus, ram_gb) = dc.vm_demands(vm)?;
        let profile = loc.placement.profile;
        let mut placed = false;
        'dest: for h in dc.hosts() {
            if h.id == host {
                continue;
            }
            for (g, gpu) in h.gpus().iter().enumerate() {
                if gpu.model() != profile.model() || !h.gpu_available(g) {
                    continue;
                }
                let r = GpuRef { host: h.id, gpu: g as u8 };
                if !view.host_fits(h.id, cpus, ram_gb) {
                    break; // CPU/RAM is host-level; no GPU here can take it
                }
                if let Some((placement, _)) = mock_assign(view.occupancy(r), profile) {
                    view.note_move(loc.gpu, loc.placement, r, placement, cpus, ram_gb);
                    plan.push_migrate(vm, loc.gpu, r, placement);
                    placed = true;
                    break 'dest;
                }
            }
        }
        if !placed {
            return None; // all-or-nothing: one stranded VM voids the drain
        }
    }
    Some(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{DataCenter, Host, HealthState, VmSpec};
    use crate::mig::{Placement, Profile};

    fn spec(id: u64, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 4, ram_gb: 8, arrival: 0, departure: 1_000, weight: 1.0 }
    }

    fn fleet() -> DataCenter {
        DataCenter::new(vec![Host::new(0, 64, 256, 2), Host::new(1, 64, 256, 2)])
    }

    #[test]
    fn empty_host_evacuates_trivially() {
        let dc = fleet();
        let plan = plan_evacuation(&dc, 0).unwrap();
        assert!(plan.is_empty());
    }

    #[test]
    fn residents_move_to_ascending_destinations() {
        let mut dc = fleet();
        let r0 = GpuRef { host: 0, gpu: 0 };
        dc.place(&spec(1, Profile::P2g10gb), r0, Placement { profile: Profile::P2g10gb, start: 0 });
        dc.place(&spec(2, Profile::P1g5gb), r0, Placement { profile: Profile::P1g5gb, start: 2 });
        let plan = plan_evacuation(&dc, 0).unwrap();
        assert_eq!(plan.num_moves(), 2);
        let mut dc2 = dc.clone();
        dc2.apply_plan(&plan).unwrap();
        assert!(dc2.vms_on_host(0).is_empty());
        assert_eq!(dc2.vms_on_host(1).len(), 2);
        dc2.check_integrity().unwrap();
    }

    #[test]
    fn unavailable_destinations_are_skipped_and_may_void_the_drain() {
        let mut dc = fleet();
        let r0 = GpuRef { host: 0, gpu: 0 };
        dc.place(&spec(1, Profile::P7g40gb), r0, Placement { profile: Profile::P7g40gb, start: 0 });
        // Knock out both GPUs of the only other host: nothing can take
        // the 7g resident, so the drain must be refused outright.
        dc.set_gpu_health(GpuRef { host: 1, gpu: 0 }, HealthState::Failed { until: 99 });
        dc.set_gpu_health(GpuRef { host: 1, gpu: 1 }, HealthState::Banned);
        assert!(plan_evacuation(&dc, 0).is_none());
        // Repair one and the plan lands there.
        dc.set_gpu_health(GpuRef { host: 1, gpu: 0 }, HealthState::Healthy);
        let plan = plan_evacuation(&dc, 0).unwrap();
        assert_eq!(plan.num_moves(), 1);
    }

    #[test]
    fn overlay_prevents_double_booking_one_destination() {
        // Two 7g residents, one healthy destination host with two GPUs:
        // the overlay must send them to *different* GPUs.
        let mut dc = fleet();
        dc.place(
            &spec(1, Profile::P7g40gb),
            GpuRef { host: 0, gpu: 0 },
            Placement { profile: Profile::P7g40gb, start: 0 },
        );
        dc.place(
            &spec(2, Profile::P7g40gb),
            GpuRef { host: 0, gpu: 1 },
            Placement { profile: Profile::P7g40gb, start: 0 },
        );
        let plan = plan_evacuation(&dc, 0).unwrap();
        assert_eq!(plan.num_moves(), 2);
        let mut dc2 = dc.clone();
        dc2.apply_plan(&plan).unwrap();
        assert!(dc2.vms_on_host(0).is_empty());
        dc2.check_integrity().unwrap();
    }
}
