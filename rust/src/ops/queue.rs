//! Bounded FIFO admission queue with per-request TTLs and priority
//! tiers.
//!
//! A retryable rejection (CPU/RAM/fragmentation — see
//! [`RejectReason::retryable`](crate::policies::RejectReason::retryable))
//! parks the request here instead of dropping it; the event core
//! re-offers queued requests to the policy once per interval before the
//! fresh batch, in FIFO order. A request that out-waits its TTL expires
//! ([`crate::policies::RejectReason::Expired`]). With preemption
//! enabled, a high-[`Tier`] arrival that cannot be placed may evict
//! low-tier residents back into the queue to make room.
//!
//! Invariants (checked by [`AdmissionQueue::verify`], exercised by the
//! ops property tests): entries are FIFO by enqueue time, deadlines are
//! non-decreasing front-to-back (uniform TTL), and occupancy never
//! exceeds the configured capacity.

use crate::cluster::vm::{Time, VmSpec, HOUR};
use std::collections::VecDeque;

/// Admission-control configuration. `capacity == 0` disables the queue
/// entirely (the default): every rejection stays terminal and the
/// decision stream is byte-identical to the pre-queue behaviour.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueConfig {
    /// Maximum queued requests; `0` disables admission queueing.
    pub capacity: usize,
    /// Time-to-live of a queued request, hours.
    pub ttl_hours: u64,
    /// May high-tier arrivals preempt low-tier residents?
    pub preemption: bool,
}

impl Default for QueueConfig {
    fn default() -> Self {
        QueueConfig { capacity: 0, ttl_hours: 24, preemption: false }
    }
}

impl QueueConfig {
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// TTL in seconds.
    pub fn ttl(&self) -> Time {
        self.ttl_hours * HOUR
    }
}

/// Priority tier of a request, derived from the paper's acceptance
/// weight `a_i` (Eq. 3): provider-defined high-priority VMs carry
/// weight ≥ 2.0. No new `VmSpec` field — traces without weights keep
/// every VM low-tier and preemption never triggers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    Low,
    High,
}

/// Tier of a VM spec (see [`Tier`]).
pub fn tier_of(spec: &VmSpec) -> Tier {
    if spec.weight >= 2.0 {
        Tier::High
    } else {
        Tier::Low
    }
}

/// One parked request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueuedRequest {
    pub spec: VmSpec,
    /// When the request entered the queue (for delay accounting).
    pub enqueued: Time,
    /// Expiry time: `enqueued + ttl`.
    pub deadline: Time,
}

/// The bounded FIFO queue. Pure container — retry/expiry *accounting*
/// (rejection counters, delay samples) lives in the event core, which
/// is the only writer.
#[derive(Debug, Clone, Default)]
pub struct AdmissionQueue {
    cfg: QueueConfig,
    q: VecDeque<QueuedRequest>,
}

impl AdmissionQueue {
    pub fn new(cfg: QueueConfig) -> AdmissionQueue {
        AdmissionQueue { cfg, q: VecDeque::new() }
    }

    pub fn config(&self) -> &QueueConfig {
        &self.cfg
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Park a request at `now`. Returns `false` (and drops nothing) if
    /// the queue is disabled or full — the caller keeps the rejection
    /// terminal in that case.
    pub fn try_enqueue(&mut self, spec: VmSpec, now: Time) -> bool {
        if self.q.len() >= self.cfg.capacity {
            return false;
        }
        self.q.push_back(QueuedRequest { spec, enqueued: now, deadline: now + self.cfg.ttl() });
        true
    }

    /// Pop every entry whose deadline has passed at `now`. Uniform TTLs
    /// make deadlines monotone front-to-back, so expired entries are
    /// exactly a prefix.
    ///
    /// Boundary contract: the deadline is **inclusive** — an entry with
    /// `deadline == now` is expired, not retried. The event core calls
    /// this with the closing interval's end time *before* its FIFO
    /// retry pass, so a VM queued at interval `t` whose TTL lapses
    /// exactly at a later retry interval's boundary counts `Expired`
    /// there; it never gets a free extra retry from the tie
    /// (`ttl_boundary` regression tests here and in `sim::event_core`).
    pub fn pop_expired(&mut self, now: Time, mut on_expire: impl FnMut(QueuedRequest)) {
        while let Some(front) = self.q.front() {
            if front.deadline > now {
                return;
            }
            on_expire(self.q.pop_front().unwrap());
        }
    }

    /// Drain the whole queue front-to-back into `out` (FIFO retry pass;
    /// the caller re-enqueues what still does not fit via
    /// [`AdmissionQueue::restore`]).
    pub fn drain_into(&mut self, out: &mut Vec<QueuedRequest>) {
        out.extend(self.q.drain(..));
    }

    /// Put back a not-yet-placeable entry, preserving FIFO order
    /// (called in drain order after [`AdmissionQueue::drain_into`]).
    pub fn restore(&mut self, req: QueuedRequest) {
        self.q.push_back(req);
    }

    /// Structural invariants: bounded occupancy, monotone deadlines and
    /// enqueue times. Used by `check_integrity`-style test assertions.
    pub fn verify(&self) -> Result<(), String> {
        if self.q.len() > self.cfg.capacity {
            return Err(format!("queue holds {} > capacity {}", self.q.len(), self.cfg.capacity));
        }
        for w in self.q.iter().zip(self.q.iter().skip(1)) {
            if w.0.deadline > w.1.deadline || w.0.enqueued > w.1.enqueued {
                return Err("queue deadlines/enqueue times not monotone".into());
            }
        }
        Ok(())
    }

    /// Iterate parked requests front-to-back (read-only).
    pub fn iter(&self) -> impl Iterator<Item = &QueuedRequest> {
        self.q.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::Profile;

    fn spec(id: u64, weight: f64) -> VmSpec {
        VmSpec {
            id,
            profile: Profile::P1g5gb,
            cpus: 2,
            ram_gb: 4,
            arrival: 0,
            departure: 10 * HOUR,
            weight,
        }
    }

    #[test]
    fn bounded_fifo_with_ttl_prefix_expiry() {
        let cfg = QueueConfig { capacity: 2, ttl_hours: 1, preemption: false };
        let mut q = AdmissionQueue::new(cfg);
        assert!(q.try_enqueue(spec(1, 1.0), 0));
        assert!(q.try_enqueue(spec(2, 1.0), 100));
        assert!(!q.try_enqueue(spec(3, 1.0), 200), "capacity bound");
        q.verify().unwrap();
        let mut expired = Vec::new();
        q.pop_expired(HOUR, |r| expired.push(r.spec.id));
        assert_eq!(expired, vec![1]); // only the t=0 entry is past its TTL
        assert_eq!(q.len(), 1);
        q.verify().unwrap();
    }

    #[test]
    fn drain_restore_preserves_order() {
        let cfg = QueueConfig { capacity: 8, ttl_hours: 24, preemption: false };
        let mut q = AdmissionQueue::new(cfg);
        for id in 1..=4 {
            assert!(q.try_enqueue(spec(id, 1.0), id));
        }
        let mut scratch = Vec::new();
        q.drain_into(&mut scratch);
        assert!(q.is_empty());
        for r in scratch {
            if r.spec.id % 2 == 0 {
                q.restore(r);
            }
        }
        let ids: Vec<u64> = q.iter().map(|r| r.spec.id).collect();
        assert_eq!(ids, vec![2, 4]);
        q.verify().unwrap();
    }

    #[test]
    fn ttl_boundary_deadline_equal_to_now_expires() {
        // The inclusive-deadline edge: a TTL lapsing *exactly* at the
        // retry boundary must expire, not slip through for another
        // retry round.
        let cfg = QueueConfig { capacity: 4, ttl_hours: 2, preemption: false };
        let mut q = AdmissionQueue::new(cfg);
        assert!(q.try_enqueue(spec(1, 1.0), HOUR)); // deadline = 3·HOUR
        let mut expired = Vec::new();
        q.pop_expired(3 * HOUR - 1, |r| expired.push(r.spec.id));
        assert!(expired.is_empty(), "one second early keeps it parked");
        q.pop_expired(3 * HOUR, |r| expired.push(r.spec.id));
        assert_eq!(expired, vec![1], "deadline == now is expired");
        assert!(q.is_empty());
    }

    #[test]
    fn tiers_derive_from_weight() {
        assert_eq!(tier_of(&spec(1, 1.0)), Tier::Low);
        assert_eq!(tier_of(&spec(2, 2.0)), Tier::High);
        assert!(Tier::High > Tier::Low);
    }

    #[test]
    fn disabled_queue_rejects_enqueues() {
        let mut q = AdmissionQueue::new(QueueConfig::default());
        assert!(!q.enabled());
        assert!(!q.try_enqueue(spec(1, 1.0), 0));
    }
}
