//! Operational events: faults, repairs, drains and admission control.
//!
//! Real fleets lose capacity — GPUs fail (ECC storms, fallen-off-the-bus
//! XIDs), whole machines reboot, and operators drain hosts for kernel
//! or driver maintenance. This module models those events
//! deterministically so placement policies can be compared under
//! degraded capacity, not just pristine fleets:
//!
//! * [`fault`] — the [`FaultInjector`]'s schedule generator: seeded
//!   exponential fail/repair processes per GPU model and per host, plus
//!   periodic maintenance drains, emitted as a sorted, byte-reproducible
//!   [`OpsEvent`] schedule the event core replays. Host failures can
//!   escalate to *correlated* domain outages (`OpsConfig::blast_radius`
//!   / `blast_hosts`, CLI `--blast-radius`) — a second seeded pass
//!   co-fails the rest of a failed host's power/network domain, which
//!   defaults to one shard of the sharded engine. Under sharding the
//!   schedule is drawn over the unsplit fleet and then split per owning
//!   shard ([`FaultInjector::into_parts`]), so the operational timeline
//!   is identical at every shard count.
//! * [`queue`] — bounded FIFO [`AdmissionQueue`] with per-request TTLs
//!   and two priority [`Tier`]s: rejected-but-retryable requests park
//!   here and re-try as capacity frees; high-tier arrivals may preempt
//!   low-tier residents back into the queue.
//! * [`evacuate`] — all-or-nothing host evacuation planning for drains,
//!   expressed as a [`crate::migrate::MigrationPlan`] through the
//!   transactional planner layer.
//!
//! Health bookkeeping itself lives on the cluster layer
//! ([`crate::cluster::HealthState`], re-exported here): the
//! `ClusterIndex` covers schedulable capacity only, and
//! `check_integrity` verifies the contract. The split keeps this module
//! free of index internals — it only speaks `set_gpu_health` /
//! `set_host_health` and the planner API.
//!
//! Determinism: the injector draws from its own PCG stream (seeded from
//! the experiment seed), never from the policy context's RNG, so a
//! zero-fault configuration is byte-identical to a build without this
//! module at all — the `ops_invariants` integration tests lock both
//! properties.

pub mod evacuate;
pub mod fault;
pub mod queue;

pub use crate::cluster::HealthState;
pub use evacuate::plan_evacuation;
pub use fault::{generate_schedule, FaultInjector, OpsConfig, OpsEvent, STATE_REPAIR_NO_HOST};
pub use queue::{tier_of, AdmissionQueue, QueueConfig, QueuedRequest, Tier};
