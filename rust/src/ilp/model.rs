//! The paper's ILP formulation (Eq. 3–26), built exactly and solved
//! lexicographically on small instances.
//!
//! Variables per §6 / Table 4: `x_ij` (VM→PM), `y_ijk` (GI→GPU), `z_ijk`
//! (starting block), `β_i` (start as a multiple of the GI size, Eq. 14–15),
//! `α_{ii'jk}` (GI ordering, Eq. 12–13), `φ_j` / `γ_jk` (powered-on
//! PM/GPU, Eq. 19–21), `m_ij` / `ω_ijk` (migration indicators, Eq. 22–25).
//!
//! The three objectives are solved lexicographically, the paper's implied
//! priority: maximize weighted acceptance (Eq. 3), then minimize active
//! hardware (Eq. 4), then minimize migrations (Eq. 5). After each stage
//! the achieved value is frozen as a constraint.
//!
//! Note the model is an *idealized bound*: it may choose any legal start
//! block, while real hardware delegates the intra-GPU choice to NVIDIA's
//! fixed policy (§5.1). Heuristic acceptance can therefore never exceed
//! the ILP's.

use super::bb::{Cmp, Milp, NodeBudget};
use crate::cluster::vm::{VmId, VmSpec};
use crate::mig::profiles::NUM_BLOCKS;
use std::collections::HashMap;

/// One host of the small instance.
#[derive(Debug, Clone, Copy)]
pub struct IlpHost {
    pub cpus: u32,
    pub ram_gb: u32,
    pub num_gpus: usize,
    /// `b_j` of Eq. 4.
    pub weight: f64,
}

/// A VM that is already placed (for the migration objective).
#[derive(Debug, Clone, Copy)]
pub struct PriorPlacement {
    pub host: usize,
    pub gpu: usize,
    /// `δ_i` of Eq. 5 (0 disables migration cost for new VMs).
    pub delta: f64,
}

/// A small placement instance.
#[derive(Debug, Clone, Default)]
pub struct PlacementInstance {
    pub hosts: Vec<IlpHost>,
    pub vms: Vec<VmSpec>,
    /// Previous assignments `x'`, `y'` for resident VMs.
    pub prior: HashMap<VmId, PriorPlacement>,
}

/// Lexicographic solution.
#[derive(Debug, Clone)]
pub struct PlacementSolution {
    /// `(host, gpu, start)` per accepted VM.
    pub assignment: HashMap<VmId, (usize, usize, u8)>,
    /// Eq. 3 value (weighted acceptance).
    pub acceptance: f64,
    /// Eq. 4 value (weighted active PMs + GPUs).
    pub active_hardware: f64,
    /// Eq. 5 value (weighted migrations).
    pub migrations: f64,
    /// Total branch-and-bound nodes across the three stages.
    pub nodes: usize,
}

/// Index bookkeeping for the flattened variable vector.
struct VarMap {
    n: usize,               // VMs
    m: usize,               // hosts
    gpus: Vec<usize>,       // GPUs per host
    gpu_offsets: Vec<usize>, // global GPU index base per host
    total_gpus: usize,
    x0: usize,
    y0: usize,
    z0: usize,
    beta0: usize,
    alpha0: usize,
    phi0: usize,
    gamma0: usize,
    mig0: usize,
    omega0: usize,
    num_vars: usize,
    pairs: Vec<(usize, usize)>, // i < i'
}

impl VarMap {
    fn new(inst: &PlacementInstance) -> VarMap {
        let n = inst.vms.len();
        let m = inst.hosts.len();
        let gpus: Vec<usize> = inst.hosts.iter().map(|h| h.num_gpus).collect();
        let mut gpu_offsets = Vec::with_capacity(m);
        let mut total = 0usize;
        for &g in &gpus {
            gpu_offsets.push(total);
            total += g;
        }
        let pairs: Vec<(usize, usize)> =
            (0..n).flat_map(|i| (i + 1..n).map(move |i2| (i, i2))).collect();
        let x0 = 0;
        let y0 = x0 + n * m;
        let z0 = y0 + n * total;
        let beta0 = z0 + n * total;
        let alpha0 = beta0 + n;
        let phi0 = alpha0 + pairs.len() * total;
        let gamma0 = phi0 + m;
        let mig0 = gamma0 + total;
        let omega0 = mig0 + n * m;
        let num_vars = omega0 + n * total;
        VarMap {
            n,
            m,
            gpus,
            gpu_offsets,
            total_gpus: total,
            x0,
            y0,
            z0,
            beta0,
            alpha0,
            phi0,
            gamma0,
            mig0,
            omega0,
            num_vars,
            pairs,
        }
    }
    fn g(&self, j: usize, k: usize) -> usize {
        self.gpu_offsets[j] + k
    }
    fn x(&self, i: usize, j: usize) -> usize {
        self.x0 + i * self.m + j
    }
    fn y(&self, i: usize, j: usize, k: usize) -> usize {
        self.y0 + i * self.total_gpus + self.g(j, k)
    }
    fn z(&self, i: usize, j: usize, k: usize) -> usize {
        self.z0 + i * self.total_gpus + self.g(j, k)
    }
    fn beta(&self, i: usize) -> usize {
        self.beta0 + i
    }
    fn alpha(&self, pair: usize, j: usize, k: usize) -> usize {
        self.alpha0 + pair * self.total_gpus + self.g(j, k)
    }
    fn phi(&self, j: usize) -> usize {
        self.phi0 + j
    }
    fn gamma(&self, j: usize, k: usize) -> usize {
        self.gamma0 + self.g(j, k)
    }
    fn mig(&self, i: usize, j: usize) -> usize {
        self.mig0 + i * self.m + j
    }
    fn omega(&self, i: usize, j: usize, k: usize) -> usize {
        self.omega0 + i * self.total_gpus + self.g(j, k)
    }
}

/// The `B` constant of Eq. 12–18: larger than any block index.
const BIG_B: f64 = NUM_BLOCKS as f64 + 1.0;

/// Builder + lexicographic solver.
pub struct IlpSolver {
    inst: PlacementInstance,
}

impl IlpSolver {
    pub fn new(inst: PlacementInstance) -> IlpSolver {
        IlpSolver { inst }
    }

    /// Build the constraint system (everything except the objective).
    fn build_base(&self, vars: &VarMap) -> Milp {
        let inst = &self.inst;
        let mut milp = Milp::new(vars.num_vars, vec![0.0; vars.num_vars], true);

        // Variable domains (Eq. 26).
        for i in 0..vars.n {
            let vm = &inst.vms[i];
            let g_i = vm.profile.size() as f64;
            let s_i = vm.profile.last_start() as f64;
            for j in 0..vars.m {
                milp.set_binary(vars.x(i, j));
                milp.set_binary(vars.mig(i, j));
                for k in 0..vars.gpus[j] {
                    milp.set_binary(vars.y(i, j, k));
                    milp.set_binary(vars.omega(i, j, k));
                    // z_ijk ∈ Z+, bounded by s_i (Eq. 16). Branch last:
                    // a fractional z of an unplaced GI is meaningless.
                    milp.set_integer(vars.z(i, j, k), 0.0, s_i);
                    milp.branch_priority[vars.z(i, j, k)] = 2;
                }
            }
            // β_i ∈ Z (Eq. 26), z = g_i β_i ≤ s_i → β ≤ s_i / g_i.
            milp.set_integer(vars.beta(i), 0.0, (s_i / g_i).floor());
            milp.branch_priority[vars.beta(i)] = 1;
        }
        for (p, _) in vars.pairs.iter().enumerate() {
            for j in 0..vars.m {
                for k in 0..vars.gpus[j] {
                    milp.set_binary(vars.alpha(p, j, k));
                }
            }
        }
        for j in 0..vars.m {
            milp.set_binary(vars.phi(j));
            for k in 0..vars.gpus[j] {
                milp.set_binary(vars.gamma(j, k));
            }
        }

        // Eq. 6–7: CPU and RAM capacities.
        for j in 0..vars.m {
            let cpu_row: Vec<(usize, f64)> =
                (0..vars.n).map(|i| (vars.x(i, j), inst.vms[i].cpus as f64)).collect();
            milp.constrain(cpu_row, Cmp::Le, inst.hosts[j].cpus as f64);
            let ram_row: Vec<(usize, f64)> =
                (0..vars.n).map(|i| (vars.x(i, j), inst.vms[i].ram_gb as f64)).collect();
            milp.constrain(ram_row, Cmp::Le, inst.hosts[j].ram_gb as f64);
        }

        for i in 0..vars.n {
            // Eq. 8: at most one PM.
            let row: Vec<(usize, f64)> = (0..vars.m).map(|j| (vars.x(i, j), 1.0)).collect();
            milp.constrain(row, Cmp::Le, 1.0);
            // Eq. 9: at most one GPU.
            let mut row = Vec::new();
            for j in 0..vars.m {
                for k in 0..vars.gpus[j] {
                    row.push((vars.y(i, j, k), 1.0));
                }
            }
            milp.constrain(row, Cmp::Le, 1.0);
            for j in 0..vars.m {
                // Eq. 10: x_ij ≤ Σ_k y_ijk.
                let mut row = vec![(vars.x(i, j), 1.0)];
                for k in 0..vars.gpus[j] {
                    row.push((vars.y(i, j, k), -1.0));
                }
                milp.constrain(row, Cmp::Le, 0.0);
                for k in 0..vars.gpus[j] {
                    // Eq. 11: y_ijk ≤ x_ij.
                    milp.constrain(
                        vec![(vars.y(i, j, k), 1.0), (vars.x(i, j), -1.0)],
                        Cmp::Le,
                        0.0,
                    );
                }
            }
        }

        // Eq. 12–13: non-overlap of GIs sharing a GPU.
        for (p, &(i, i2)) in vars.pairs.iter().enumerate() {
            let g_i = inst.vms[i].profile.size() as f64;
            let g_i2 = inst.vms[i2].profile.size() as f64;
            for j in 0..vars.m {
                for k in 0..vars.gpus[j] {
                    // z_i + g_i y_i ≤ z_i' + B α  (+B slack unless both placed)
                    milp.constrain(
                        vec![
                            (vars.z(i, j, k), 1.0),
                            (vars.y(i, j, k), g_i),
                            (vars.z(i2, j, k), -1.0),
                            (vars.alpha(p, j, k), -BIG_B),
                        ],
                        Cmp::Le,
                        0.0,
                    );
                    // z_i' + g_i' y_i' ≤ z_i + B(1-α)
                    milp.constrain(
                        vec![
                            (vars.z(i2, j, k), 1.0),
                            (vars.y(i2, j, k), g_i2),
                            (vars.z(i, j, k), -1.0),
                            (vars.alpha(p, j, k), BIG_B),
                        ],
                        Cmp::Le,
                        BIG_B,
                    );
                }
            }
        }

        // Eq. 14–16: z = g_i β_i when placed, z ≤ s_i.
        for i in 0..vars.n {
            let vm = &inst.vms[i];
            let g_i = vm.profile.size() as f64;
            for j in 0..vars.m {
                for k in 0..vars.gpus[j] {
                    // z ≤ g β + B(1-y)
                    milp.constrain(
                        vec![
                            (vars.z(i, j, k), 1.0),
                            (vars.beta(i), -g_i),
                            (vars.y(i, j, k), BIG_B),
                        ],
                        Cmp::Le,
                        BIG_B,
                    );
                    // -z ≤ -g β + B(1-y)
                    milp.constrain(
                        vec![
                            (vars.z(i, j, k), -1.0),
                            (vars.beta(i), g_i),
                            (vars.y(i, j, k), BIG_B),
                        ],
                        Cmp::Le,
                        BIG_B,
                    );
                    // Eq. 17–18 (h_i = H_jk = 100 for A100-only clusters)
                    // are trivially satisfied; a heterogeneous extension
                    // would forbid y_ijk here instead.
                }
            }
        }

        // Eq. 19–21: power indicators.
        for i in 0..vars.n {
            for j in 0..vars.m {
                milp.constrain(vec![(vars.x(i, j), 1.0), (vars.phi(j), -1.0)], Cmp::Le, 0.0);
                for k in 0..vars.gpus[j] {
                    milp.constrain(
                        vec![(vars.y(i, j, k), 1.0), (vars.gamma(j, k), -1.0)],
                        Cmp::Le,
                        0.0,
                    );
                }
            }
        }
        for j in 0..vars.m {
            for k in 0..vars.gpus[j] {
                // Eq. 21: γ_jk ≤ Σ_i y_ijk.
                let mut row = vec![(vars.gamma(j, k), 1.0)];
                for i in 0..vars.n {
                    row.push((vars.y(i, j, k), -1.0));
                }
                milp.constrain(row, Cmp::Le, 0.0);
            }
        }

        // Symmetry breaking (valid only without prior placements, when
        // identical hosts/GPUs are interchangeable): order the power
        // indicators — φ_j ≥ φ_{j+1} for identical adjacent hosts,
        // γ_{j,k} ≥ γ_{j,k+1} within each host. Cuts factorially many
        // equivalent branch-and-bound subtrees.
        if self.inst.prior.is_empty() {
            for j in 0..vars.m.saturating_sub(1) {
                let (a, b) = (&inst.hosts[j], &inst.hosts[j + 1]);
                if a.cpus == b.cpus
                    && a.ram_gb == b.ram_gb
                    && a.num_gpus == b.num_gpus
                    && a.weight == b.weight
                {
                    milp.constrain(
                        vec![(vars.phi(j), 1.0), (vars.phi(j + 1), -1.0)],
                        Cmp::Ge,
                        0.0,
                    );
                }
            }
            for j in 0..vars.m {
                for k in 0..vars.gpus[j].saturating_sub(1) {
                    milp.constrain(
                        vec![(vars.gamma(j, k), 1.0), (vars.gamma(j, k + 1), -1.0)],
                        Cmp::Ge,
                        0.0,
                    );
                }
            }
        }

        // Eq. 22–25: migration indicators vs prior assignment.
        for i in 0..vars.n {
            let prior = self.inst.prior.get(&inst.vms[i].id);
            for j in 0..vars.m {
                let x_prev = match prior {
                    Some(p) if p.host == j => 1.0,
                    _ => 0.0,
                };
                // x - x' ≤ m and x' - x ≤ m.
                milp.constrain(
                    vec![(vars.x(i, j), 1.0), (vars.mig(i, j), -1.0)],
                    Cmp::Le,
                    x_prev,
                );
                milp.constrain(
                    vec![(vars.x(i, j), -1.0), (vars.mig(i, j), -1.0)],
                    Cmp::Le,
                    -x_prev,
                );
                for k in 0..vars.gpus[j] {
                    let y_prev = match prior {
                        Some(p) if p.host == j && p.gpu == k => 1.0,
                        _ => 0.0,
                    };
                    milp.constrain(
                        vec![(vars.y(i, j, k), 1.0), (vars.omega(i, j, k), -1.0)],
                        Cmp::Le,
                        y_prev,
                    );
                    milp.constrain(
                        vec![(vars.y(i, j, k), -1.0), (vars.omega(i, j, k), -1.0)],
                        Cmp::Le,
                        -y_prev,
                    );
                }
            }
        }

        milp
    }

    fn objective_acceptance(&self, vars: &VarMap) -> Vec<f64> {
        let mut c = vec![0.0; vars.num_vars];
        for i in 0..vars.n {
            for j in 0..vars.m {
                c[vars.x(i, j)] = self.inst.vms[i].weight;
            }
        }
        c
    }

    fn objective_hardware(&self, vars: &VarMap) -> Vec<f64> {
        let mut c = vec![0.0; vars.num_vars];
        for j in 0..vars.m {
            c[vars.phi(j)] = self.inst.hosts[j].weight;
            for k in 0..vars.gpus[j] {
                c[vars.gamma(j, k)] = self.inst.hosts[j].weight;
            }
        }
        c
    }

    fn objective_migrations(&self, vars: &VarMap) -> Vec<f64> {
        let mut c = vec![0.0; vars.num_vars];
        for i in 0..vars.n {
            let delta =
                self.inst.prior.get(&self.inst.vms[i].id).map(|p| p.delta).unwrap_or(0.0);
            for j in 0..vars.m {
                c[vars.mig(i, j)] = delta;
                for k in 0..vars.gpus[j] {
                    c[vars.omega(i, j, k)] = delta;
                }
            }
        }
        c
    }

    /// Solve the three objectives lexicographically, exactly (no node
    /// cap). Equivalent to
    /// [`IlpSolver::solve_budgeted`]`(NodeBudget::Unlimited)`.
    pub fn solve(&self) -> Option<PlacementSolution> {
        self.solve_budgeted(NodeBudget::Unlimited)
    }

    /// Solve under the legacy sentinel encoding (`0` = unlimited).
    /// Compatibility wrapper over [`IlpSolver::solve_budgeted`]; new
    /// call sites should pass a [`NodeBudget`] directly.
    pub fn solve_limited(&self, node_limit: usize) -> Option<PlacementSolution> {
        self.solve_budgeted(NodeBudget::from_limit(node_limit))
    }

    /// Solve the three objectives lexicographically under a
    /// branch-and-bound node budget per stage. A truncated stage
    /// returns its incumbent — still a *feasible* solution, just not a
    /// proven optimum — and the later stages freeze against that
    /// incumbent, so the result is always a valid (possibly suboptimal)
    /// placement. Returns `None` only when a stage finds no incumbent
    /// inside the budget. Deterministic: same instance + same budget →
    /// byte-identical solution (the `bb` module's determinism
    /// contract).
    pub fn solve_budgeted(&self, budget: NodeBudget) -> Option<PlacementSolution> {
        let vars = VarMap::new(&self.inst);
        let mut milp = self.build_base(&vars);
        let mut nodes = 0usize;

        // Objectives over binary variables with integer weights have
        // integral values — unlock the unit pruning gap.
        let integral = |c: &[f64]| c.iter().all(|v| v.fract() == 0.0);

        // Stage 1: maximize acceptance.
        let c1 = self.objective_acceptance(&vars);
        milp.objective = c1.clone();
        milp.integral_objective = integral(&c1);
        milp.maximize = true;
        let s1 = milp.solve_with(budget)?;
        nodes += s1.nodes;
        let acceptance = s1.objective;
        let row: Vec<(usize, f64)> =
            c1.iter().enumerate().filter(|(_, &v)| v != 0.0).map(|(i, &v)| (i, v)).collect();
        milp.constrain(row, Cmp::Ge, acceptance - 0.25);

        // Stage 2: minimize active hardware.
        let c2 = self.objective_hardware(&vars);
        milp.objective = c2.clone();
        milp.integral_objective = integral(&c2);
        milp.maximize = false;
        let s2 = milp.solve_with(budget)?;
        nodes += s2.nodes;
        let active = s2.objective;
        let row: Vec<(usize, f64)> =
            c2.iter().enumerate().filter(|(_, &v)| v != 0.0).map(|(i, &v)| (i, v)).collect();
        milp.constrain(row, Cmp::Le, active + 0.25);

        // Stage 3: minimize migrations.
        let c3 = self.objective_migrations(&vars);
        let all_zero = c3.iter().all(|&v| v == 0.0);
        milp.integral_objective = integral(&c3);
        milp.objective = c3;
        milp.maximize = false;
        let s3 = if all_zero {
            // No resident VMs: stage 2's solution is final.
            s2.clone()
        } else {
            let s = milp.solve_with(budget)?;
            nodes += s.nodes;
            s
        };
        let migrations = if all_zero { 0.0 } else { s3.objective };

        // Extract the assignment from the final solution vector.
        let values = &s3.values;
        let mut assignment = HashMap::new();
        for i in 0..vars.n {
            for j in 0..vars.m {
                for k in 0..vars.gpus[j] {
                    if values[vars.y(i, j, k)] > 0.5 {
                        let start = values[vars.z(i, j, k)].round() as u8;
                        assignment.insert(self.inst.vms[i].id, (j, k, start));
                    }
                }
            }
        }
        Some(PlacementSolution {
            assignment,
            acceptance,
            active_hardware: active,
            migrations,
            nodes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::Profile;

    fn vm(id: VmId, profile: Profile, weight: f64) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 10, weight }
    }

    fn host(num_gpus: usize) -> IlpHost {
        IlpHost { cpus: 64, ram_gb: 256, num_gpus, weight: 1.0 }
    }

    #[test]
    fn single_vm_single_gpu() {
        let inst = PlacementInstance {
            hosts: vec![host(1)],
            vms: vec![vm(1, Profile::P3g20gb, 1.0)],
            prior: HashMap::new(),
        };
        let s = IlpSolver::new(inst).solve().unwrap();
        assert!((s.acceptance - 1.0).abs() < 1e-6);
        // 1 PM + 1 GPU active.
        assert!((s.active_hardware - 2.0).abs() < 1e-6);
        let (_, _, start) = s.assignment[&1];
        assert!(start == 0 || start == 4);
    }

    #[test]
    fn two_3g_share_one_gpu() {
        let inst = PlacementInstance {
            hosts: vec![host(2)],
            vms: vec![vm(1, Profile::P3g20gb, 1.0), vm(2, Profile::P3g20gb, 1.0)],
            prior: HashMap::new(),
        };
        let s = IlpSolver::new(inst).solve().unwrap();
        assert!((s.acceptance - 2.0).abs() < 1e-6);
        // Hardware-minimal: both on one GPU → 1 PM + 1 GPU = 2.
        assert!((s.active_hardware - 2.0).abs() < 1e-6, "{s:?}");
        let (_, k1, s1) = s.assignment[&1];
        let (_, k2, s2) = s.assignment[&2];
        assert_eq!(k1, k2);
        assert_ne!(s1, s2);
        assert_eq!(s1.min(s2), 0);
        assert_eq!(s1.max(s2), 4);
    }

    #[test]
    fn capacity_forces_rejection() {
        // Two 7g.40gb on one GPU: only one fits.
        let inst = PlacementInstance {
            hosts: vec![host(1)],
            vms: vec![vm(1, Profile::P7g40gb, 1.0), vm(2, Profile::P7g40gb, 1.0)],
            prior: HashMap::new(),
        };
        let s = IlpSolver::new(inst).solve().unwrap();
        assert!((s.acceptance - 1.0).abs() < 1e-6);
        assert_eq!(s.assignment.len(), 1);
    }

    #[test]
    fn weights_prioritize_large_vm() {
        // One GPU; a 7g (weight 5) vs two 1g (weight 1 each): accepting
        // the 7g wins 5 > 2.
        let inst = PlacementInstance {
            hosts: vec![host(1)],
            vms: vec![
                vm(1, Profile::P7g40gb, 5.0),
                vm(2, Profile::P1g5gb, 1.0),
                vm(3, Profile::P1g5gb, 1.0),
            ],
            prior: HashMap::new(),
        };
        let s = IlpSolver::new(inst).solve().unwrap();
        assert!((s.acceptance - 5.0).abs() < 1e-6);
        assert!(s.assignment.contains_key(&1));
        assert!(!s.assignment.contains_key(&2));
    }

    #[test]
    fn cpu_constraint_respected() {
        // Host CPU fits only one VM despite GPU space for both.
        let inst = PlacementInstance {
            hosts: vec![IlpHost { cpus: 3, ram_gb: 256, num_gpus: 1, weight: 1.0 }],
            vms: vec![vm(1, Profile::P1g5gb, 1.0), vm(2, Profile::P1g5gb, 1.0)],
            prior: HashMap::new(),
        };
        let s = IlpSolver::new(inst).solve().unwrap();
        assert!((s.acceptance - 1.0).abs() < 1e-6);
    }

    #[test]
    fn consolidation_preferred_over_spreading() {
        // Two hosts, one GPU each; two 2g VMs → both on one host.
        let inst = PlacementInstance {
            hosts: vec![host(1), host(1)],
            vms: vec![vm(1, Profile::P2g10gb, 1.0), vm(2, Profile::P2g10gb, 1.0)],
            prior: HashMap::new(),
        };
        let s = IlpSolver::new(inst).solve().unwrap();
        assert!((s.acceptance - 2.0).abs() < 1e-6);
        assert!((s.active_hardware - 2.0).abs() < 1e-6);
        let (j1, _, _) = s.assignment[&1];
        let (j2, _, _) = s.assignment[&2];
        assert_eq!(j1, j2);
    }

    #[test]
    fn migration_minimized_for_resident_vm() {
        // VM 1 already on host 0; consolidating onto host 1 would not
        // change hardware count, so stage 3 keeps it in place.
        let mut prior = HashMap::new();
        prior.insert(1, PriorPlacement { host: 0, gpu: 0, delta: 1.0 });
        let inst = PlacementInstance {
            hosts: vec![host(1), host(1)],
            vms: vec![vm(1, Profile::P2g10gb, 1.0), vm(2, Profile::P2g10gb, 1.0)],
            prior,
        };
        let s = IlpSolver::new(inst).solve().unwrap();
        assert!((s.acceptance - 2.0).abs() < 1e-6);
        assert!((s.migrations - 0.0).abs() < 1e-6, "{s:?}");
        let (j1, _, _) = s.assignment[&1];
        assert_eq!(j1, 0, "resident VM should stay on host 0");
        let (j2, _, _) = s.assignment[&2];
        assert_eq!(j2, 0, "new VM joins the already-active host");
    }

    #[test]
    fn start_blocks_are_legal_multiples() {
        // A 2g.10gb's start must be ∈ {0, 2, 4}: fill a GPU with one
        // 1g.10gb and one 2g.10gb and check both starts are even.
        let inst = PlacementInstance {
            hosts: vec![host(1)],
            vms: vec![vm(1, Profile::P1g10gb, 1.0), vm(2, Profile::P2g10gb, 1.0)],
            prior: HashMap::new(),
        };
        let s = IlpSolver::new(inst).solve().unwrap();
        assert_eq!(s.assignment.len(), 2);
        for (_, (_, _, start)) in &s.assignment {
            assert_eq!(start % 2, 0, "{s:?}");
        }
        // And 2g.10gb specifically must not start at 6.
        let (_, _, s2) = s.assignment[&2];
        assert!(s2 <= 4);
    }
}
