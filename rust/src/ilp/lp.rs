//! Dense two-phase primal simplex.
//!
//! Solves `maximize c·x  s.t.  A x ≤ b,  x ≥ 0` with `b` of any sign
//! (phase 1 drives artificial variables out of the basis). Bland's rule
//! avoids cycling; sizes here are small (hundreds of rows), so the dense
//! tableau is simple and fast enough.

/// Result of an LP solve.
#[derive(Debug, Clone, PartialEq)]
pub enum LpOutcome {
    /// Optimal solution: variable values and objective.
    Optimal { x: Vec<f64>, objective: f64 },
    Infeasible,
    Unbounded,
}

/// `maximize c·x  s.t.  rows·x ≤ rhs, x ≥ 0`.
#[derive(Debug, Clone, Default)]
pub struct LinearProgram {
    pub num_vars: usize,
    pub objective: Vec<f64>,
    /// Each row: dense coefficients (len = num_vars) and right-hand side.
    pub rows: Vec<Vec<f64>>,
    pub rhs: Vec<f64>,
}

const EPS: f64 = 1e-9;

impl LinearProgram {
    pub fn new(num_vars: usize, objective: Vec<f64>) -> LinearProgram {
        assert_eq!(objective.len(), num_vars);
        LinearProgram { num_vars, objective, rows: Vec::new(), rhs: Vec::new() }
    }

    /// Add `coeffs·x ≤ rhs` from a sparse coefficient list.
    pub fn add_le(&mut self, coeffs: &[(usize, f64)], rhs: f64) {
        let mut row = vec![0.0; self.num_vars];
        for &(i, c) in coeffs {
            row[i] += c;
        }
        self.rows.push(row);
        self.rhs.push(rhs);
    }

    /// Add `coeffs·x ≥ rhs` (stored as `-coeffs·x ≤ -rhs`).
    pub fn add_ge(&mut self, coeffs: &[(usize, f64)], rhs: f64) {
        let neg: Vec<(usize, f64)> = coeffs.iter().map(|&(i, c)| (i, -c)).collect();
        self.add_le(&neg, -rhs);
    }

    /// Add `coeffs·x = rhs` (as ≤ and ≥).
    pub fn add_eq(&mut self, coeffs: &[(usize, f64)], rhs: f64) {
        self.add_le(coeffs, rhs);
        self.add_ge(coeffs, rhs);
    }

    /// Solve with the two-phase simplex.
    pub fn solve(&self) -> LpOutcome {
        let m = self.rows.len();
        let n = self.num_vars;

        // Tableau layout: columns [structural n | slacks m | artificials a | rhs].
        // Normalize rows to have rhs >= 0; rows that flip sign get their
        // slack with coefficient -1 and need an artificial variable.
        let mut need_artificial: Vec<bool> = vec![false; m];
        let mut num_art = 0;
        for i in 0..m {
            if self.rhs[i] < -EPS {
                need_artificial[i] = true;
                num_art += 1;
            }
        }
        let cols = n + m + num_art + 1;
        let mut t = vec![vec![0.0; cols]; m];
        let mut basis: Vec<usize> = vec![0; m];
        let mut art_idx = 0;
        for i in 0..m {
            let flip = if need_artificial[i] { -1.0 } else { 1.0 };
            for j in 0..n {
                t[i][j] = flip * self.rows[i][j];
            }
            t[i][n + i] = flip; // slack (negative surplus when flipped)
            t[i][cols - 1] = flip * self.rhs[i];
            if need_artificial[i] {
                let a_col = n + m + art_idx;
                t[i][a_col] = 1.0;
                basis[i] = a_col;
                art_idx += 1;
            } else {
                basis[i] = n + i;
            }
        }

        // Phase 1: minimize sum of artificials (maximize -sum).
        if num_art > 0 {
            let mut obj1 = vec![0.0; cols - 1];
            for a in 0..num_art {
                obj1[n + m + a] = -1.0;
            }
            let feasible = simplex_core(&mut t, &mut basis, &obj1);
            match feasible {
                CoreOutcome::Unbounded => return LpOutcome::Infeasible, // cannot happen
                CoreOutcome::Optimal(z) => {
                    if z < -1e-6 {
                        return LpOutcome::Infeasible;
                    }
                }
            }
            // Drive any artificial still in the basis out (degenerate);
            // if its row is all-zero over real columns it is redundant.
            for i in 0..m {
                if basis[i] >= n + m {
                    let pivot_col = (0..n + m).find(|&j| t[i][j].abs() > EPS);
                    if let Some(j) = pivot_col {
                        pivot(&mut t, &mut basis, i, j);
                    }
                }
            }
        }

        // Phase 2: original objective (zero on slack/artificial columns;
        // artificial columns are forced to stay at 0 by never entering).
        let mut obj2 = vec![0.0; cols - 1];
        obj2[..n].copy_from_slice(&self.objective);
        // Forbid artificials from re-entering.
        for a in 0..num_art {
            obj2[n + m + a] = f64::NEG_INFINITY;
        }
        match simplex_core(&mut t, &mut basis, &obj2) {
            CoreOutcome::Unbounded => LpOutcome::Unbounded,
            CoreOutcome::Optimal(z) => {
                let mut x = vec![0.0; n];
                let cols = t[0].len();
                for i in 0..m {
                    if basis[i] < n {
                        x[basis[i]] = t[i][cols - 1];
                    }
                }
                LpOutcome::Optimal { x, objective: z }
            }
        }
    }
}

enum CoreOutcome {
    Optimal(f64),
    Unbounded,
}

/// Run primal simplex on the tableau with the given objective row.
///
/// Maintains the reduced-cost row incrementally (pivoted together with
/// the constraint rows) instead of recomputing `c_B · B⁻¹A_j` per
/// column — the difference between O(m·n) and O(m·n²) per pivot, which
/// dominates branch-and-bound time on the Eq. 3–26 instances.
fn simplex_core(t: &mut [Vec<f64>], basis: &mut [usize], obj: &[f64]) -> CoreOutcome {
    let m = t.len();
    let cols = t[0].len();
    let ncols = cols - 1;

    // Build the reduced-cost row: r_j = c_j - c_B · B^{-1} A_j, and the
    // current objective value in the rhs slot.
    let cost = |j: usize| -> f64 {
        let c = obj[j];
        if c == f64::NEG_INFINITY {
            0.0
        } else {
            c
        }
    };
    let mut red = vec![0.0f64; cols];
    for j in 0..ncols {
        let mut zj = 0.0;
        for i in 0..m {
            let cb = cost(basis[i]);
            if cb != 0.0 {
                zj += cb * t[i][j];
            }
        }
        red[j] = cost(j) - zj;
    }
    // rhs slot stores -z so the whole row pivots uniformly like a
    // constraint row ([c - c_B·B⁻¹A | -z] stays of that form).
    let mut zval = 0.0;
    for i in 0..m {
        let cb = cost(basis[i]);
        if cb != 0.0 {
            zval += cb * t[i][cols - 1];
        }
    }
    red[cols - 1] = -zval;

    let mut iter = 0usize;
    let max_iter = 50_000;
    // Dantzig's rule normally; degenerate stalls (no objective progress
    // for a stretch) switch permanently to Bland's rule, which cannot
    // cycle.
    let mut bland_mode = false;
    let mut last_z = f64::NEG_INFINITY;
    let mut stall = 0usize;
    loop {
        iter += 1;
        if iter > max_iter {
            if std::env::var("GRMU_ILP_DEBUG").is_ok() {
                eprintln!("[lp] max_iter hit (m={m}, cols={cols}, z={})", -red[cols - 1]);
            }
            return CoreOutcome::Optimal(-red[cols - 1]);
        }
        let z = -red[cols - 1];
        if z > last_z + 1e-9 {
            last_z = z;
            stall = 0;
        } else {
            stall += 1;
            if stall > 64 {
                bland_mode = true;
            }
        }
        let mut entering: Option<usize> = None;
        let mut best = 1e-7;
        for j in 0..ncols {
            if obj[j] == f64::NEG_INFINITY {
                continue; // barred column (artificials in phase 2)
            }
            if red[j] > best {
                entering = Some(j);
                if bland_mode {
                    break;
                }
                best = red[j];
            }
        }
        let Some(e) = entering else {
            return CoreOutcome::Optimal(-red[cols - 1]);
        };
        // Ratio test (Bland: smallest basis index on ties).
        let mut leave: Option<usize> = None;
        let mut best_ratio = f64::INFINITY;
        for i in 0..m {
            if t[i][e] > EPS {
                let ratio = t[i][cols - 1] / t[i][e];
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && leave.map(|l| basis[i] < basis[l]).unwrap_or(true))
                {
                    best_ratio = ratio;
                    leave = Some(i);
                }
            }
        }
        let Some(l) = leave else {
            return CoreOutcome::Unbounded;
        };
        pivot(t, basis, l, e);
        // Pivot the reduced-cost row as well.
        let f = red[e];
        if f.abs() > EPS {
            for j in 0..cols {
                red[j] -= f * t[l][j];
            }
        }
        // The entering column's reduced cost is exactly zero now.
        red[e] = 0.0;
    }
}

fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize) {
    let cols = t[0].len();
    let p = t[row][col];
    debug_assert!(p.abs() > EPS);
    for j in 0..cols {
        t[row][j] /= p;
    }
    for i in 0..t.len() {
        if i != row && t[i][col].abs() > EPS {
            let f = t[i][col];
            for j in 0..cols {
                t[i][j] -= f * t[row][j];
            }
        }
    }
    basis[row] = col;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_opt(outcome: &LpOutcome, expect_obj: f64, expect_x: Option<&[f64]>) {
        match outcome {
            LpOutcome::Optimal { x, objective } => {
                assert!(
                    (objective - expect_obj).abs() < 1e-6,
                    "objective {objective} vs {expect_obj}"
                );
                if let Some(ex) = expect_x {
                    for (a, b) in x.iter().zip(ex) {
                        assert!((a - b).abs() < 1e-6, "x={x:?} vs {ex:?}");
                    }
                }
            }
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn textbook_2d() {
        // max 3x + 5y, x ≤ 4, 2y ≤ 12, 3x + 2y ≤ 18 → (2, 6), z = 36.
        let mut lp = LinearProgram::new(2, vec![3.0, 5.0]);
        lp.add_le(&[(0, 1.0)], 4.0);
        lp.add_le(&[(1, 2.0)], 12.0);
        lp.add_le(&[(0, 3.0), (1, 2.0)], 18.0);
        assert_opt(&lp.solve(), 36.0, Some(&[2.0, 6.0]));
    }

    #[test]
    fn needs_phase_one() {
        // max x + y, x + y ≥ 2, x ≤ 3, y ≤ 3 → 6 at (3,3).
        let mut lp = LinearProgram::new(2, vec![1.0, 1.0]);
        lp.add_ge(&[(0, 1.0), (1, 1.0)], 2.0);
        lp.add_le(&[(0, 1.0)], 3.0);
        lp.add_le(&[(1, 1.0)], 3.0);
        assert_opt(&lp.solve(), 6.0, None);
    }

    #[test]
    fn minimization_via_negation() {
        // min x + 2y s.t. x + y ≥ 4, y ≥ 1 → (3,1), obj 5.
        let mut lp = LinearProgram::new(2, vec![-1.0, -2.0]);
        lp.add_ge(&[(0, 1.0), (1, 1.0)], 4.0);
        lp.add_ge(&[(1, 1.0)], 1.0);
        match lp.solve() {
            LpOutcome::Optimal { objective, x } => {
                assert!((objective + 5.0).abs() < 1e-6, "obj={objective} x={x:?}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn infeasible_detected() {
        // x ≤ 1 and x ≥ 2.
        let mut lp = LinearProgram::new(1, vec![1.0]);
        lp.add_le(&[(0, 1.0)], 1.0);
        lp.add_ge(&[(0, 1.0)], 2.0);
        assert_eq!(lp.solve(), LpOutcome::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut lp = LinearProgram::new(1, vec![1.0]);
        lp.add_ge(&[(0, 1.0)], 0.0);
        assert_eq!(lp.solve(), LpOutcome::Unbounded);
    }

    #[test]
    fn equality_constraints() {
        // max x + y s.t. x + y = 5, x ≤ 2 → 5 with x ≤ 2.
        let mut lp = LinearProgram::new(2, vec![1.0, 1.0]);
        lp.add_eq(&[(0, 1.0), (1, 1.0)], 5.0);
        lp.add_le(&[(0, 1.0)], 2.0);
        assert_opt(&lp.solve(), 5.0, None);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Classic degenerate LP (Beale-like); just require termination
        // at the known optimum 0.05.
        let mut lp = LinearProgram::new(4, vec![0.75, -150.0, 0.02, -6.0]);
        lp.add_le(&[(0, 0.25), (1, -60.0), (2, -0.04), (3, 9.0)], 0.0);
        lp.add_le(&[(0, 0.5), (1, -90.0), (2, -0.02), (3, 3.0)], 0.0);
        lp.add_le(&[(2, 1.0)], 1.0);
        match lp.solve() {
            LpOutcome::Optimal { objective, .. } => {
                assert!((objective - 0.05).abs() < 1e-6, "obj={objective}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn binding_mix_larger() {
        // Knapsack LP relaxation: max 10a+6b+4c, a+b+c ≤ 100,
        // 10a+4b+5c ≤ 600, 2a+2b+6c ≤ 300 → z = 733.33...
        let mut lp = LinearProgram::new(3, vec![10.0, 6.0, 4.0]);
        lp.add_le(&[(0, 1.0), (1, 1.0), (2, 1.0)], 100.0);
        lp.add_le(&[(0, 10.0), (1, 4.0), (2, 5.0)], 600.0);
        lp.add_le(&[(0, 2.0), (1, 2.0), (2, 6.0)], 300.0);
        match lp.solve() {
            LpOutcome::Optimal { objective, .. } => {
                assert!((objective - 2200.0 / 3.0).abs() < 1e-4);
            }
            other => panic!("{other:?}"),
        }
    }
}
