//! The paper's multi-objective ILP (Eq. 3–26) and an exact in-house MILP
//! solver.
//!
//! §7 argues the full formulation is intractable ("even a solver cannot
//! handle it within a viable timeframe"); the paper therefore never
//! solves it. We go one step further than the paper: [`lp`] implements a
//! dense two-phase simplex, [`bb`] a branch-and-bound MILP on top of it,
//! and [`model`] builds Eq. 3–26 exactly and solves the three objectives
//! *lexicographically* (acceptance ≻ active hardware ≻ migrations) on
//! small instances. `examples/ilp_validation.rs` and the integration
//! tests use it as ground truth for the heuristics. [`online`] takes the
//! solver live: a rolling-horizon repair planner over bounded windows of
//! the running cluster, plus per-policy optimality-gap metering.

pub mod bb;
pub mod lp;
pub mod model;
pub mod online;

pub use bb::{Cmp, Milp, MilpSolution, NodeBudget};
pub use lp::{LinearProgram, LpOutcome};
pub use model::{IlpSolver, PlacementInstance, PlacementSolution};
pub use online::{GapMeter, RollingIlp};
