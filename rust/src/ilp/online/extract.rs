//! Bounded [`PlacementInstance`] extraction from the live cluster.
//!
//! The full-fleet ILP is intractable (§7); the online planner therefore
//! carves a *bounded* instance out of the cluster: the most fragmented
//! `K` schedulable GPUs of one model (plus the interval's pending
//! rejects of that model) become a [`PlacementInstance`] the
//! branch-and-bound can solve under a node budget.
//!
//! ## Determinism contract
//!
//! Instance extraction is a pure function of the cluster state:
//!
//! * The window ranks GPUs by fragmentation *descending* with ties
//!   resolved to the lowest [`GpuRef`] (ascending `globalIndex` — the
//!   scope order — preserved by a stable sort).
//! * Hosts and GPUs enter the instance in ascending `GpuRef` order, so
//!   the solver's dense variable indices — and with them the
//!   branch-and-bound's lowest-index tie-breaks — are reproducible.
//! * Resident VMs enter in (GPU, on-device instance) order; pending
//!   VMs after them, in batch order.
//!
//! Together with the `ilp::bb` determinism contract this makes every
//! online solve byte-reproducible and thread-count independent.
//!
//! ## Health contract
//!
//! Only schedulable GPUs ([`DataCenter::gpu_available`]: device *and*
//! host `Healthy`) enter the window. `Draining` capacity allows
//! residency but not placement, so a draining GPU's residents belong to
//! the drain evacuation — never to an ILP repair plan — and failed or
//! banned capacity is invisible here entirely. `rust/tests/
//! ops_invariants.rs` asserts this.

use crate::cluster::vm::{VmId, VmSpec};
use crate::cluster::{DataCenter, GpuRef};
use crate::ilp::model::{IlpHost, PlacementInstance, PriorPlacement};
use crate::mig::fragmentation::fragmentation_value;
use crate::mig::GpuModel;
use crate::migrate::PlanScope;
use std::collections::HashMap;

/// Hard cap on VMs per extracted instance. The solver's variable count
/// grows as `n · (hosts + 3·GPUs)`; 24 VMs over an 8-GPU window stays
/// well inside what the node-budgeted branch-and-bound turns into a
/// useful incumbent.
pub const MAX_INSTANCE_VMS: usize = 24;

/// Prior-VM weight used by *repair* extraction: so much heavier than
/// any real request weight that stage 1 (acceptance) never trades a
/// resident away for pending demand — repair plans relocate, they never
/// evict.
pub const REPAIR_WEIGHT: f64 = 1e6;

/// Map from an instance's dense (host, gpu) indices back to the live
/// cluster's [`GpuRef`]s.
#[derive(Debug, Clone, Default)]
pub struct InstanceMap {
    /// `gpus[j][k]` = the `GpuRef` behind instance host `j`, GPU `k`.
    pub gpus: Vec<Vec<GpuRef>>,
}

impl InstanceMap {
    /// The live GPU behind instance coordinates `(j, k)`.
    #[inline]
    pub fn gpu(&self, j: usize, k: usize) -> GpuRef {
        self.gpus[j][k]
    }
}

/// A bounded instance plus the bookkeeping needed to act on its
/// solution.
#[derive(Debug, Clone, Default)]
pub struct ExtractedInstance {
    pub inst: PlacementInstance,
    pub map: InstanceMap,
    /// Ids of the pending specs that made it into the instance (the
    /// VM cap may have truncated the tail).
    pub included_pending: Vec<VmId>,
}

/// The `k` most fragmented schedulable GPUs of `model` within `scope`,
/// in the deterministic ranking order (fragmentation descending, ties
/// to the lowest `GpuRef`). Unschedulable capacity — failed, banned or
/// draining devices, or any GPU on a non-`Healthy` host — never enters
/// the window.
pub fn fragmented_window(
    dc: &DataCenter,
    scope: PlanScope,
    model: GpuModel,
    k: usize,
) -> Vec<GpuRef> {
    let mut scored: Vec<(f64, GpuRef)> = Vec::new();
    match scope {
        // Cluster scope reads the index's per-model schedulable set
        // directly: same GPUs, same ascending order as the filtered
        // fleet walk below, without touching foreign-model or offline
        // capacity at all.
        PlanScope::Cluster => {
            for r in dc.index().schedulable(model) {
                scored.push((fragmentation_value(model, dc.gpu(r).occupancy()), r));
            }
        }
        _ => {
            for r in scope.gpus(dc) {
                if !dc.gpu_available(r) {
                    continue;
                }
                let gpu = dc.gpu(r);
                if gpu.model() != model {
                    continue;
                }
                scored.push((fragmentation_value(model, gpu.occupancy()), r));
            }
        }
    }
    // Stable sort: equal fragmentation keeps the ascending-GpuRef scope
    // order, so ties resolve to the lowest globalIndex.
    scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
    scored.truncate(k);
    scored.into_iter().map(|(_, r)| r).collect()
}

/// Build a [`PlacementInstance`] from a ranked window (the output of
/// [`fragmented_window`]) plus pending rejects. All window GPUs must
/// share one model; pending specs of other models are skipped.
///
/// `weight_of` supplies the acceptance weight of each *resident* VM
/// (repair extraction passes a constant [`REPAIR_WEIGHT`]; the gap
/// estimator passes the true weights it tracked). Pending specs keep
/// their own weights.
///
/// The `max_vms` cap is enforced by first truncating pending (tail
/// first) and then, if the residents alone still exceed it, dropping
/// the least fragmented window GPUs (the tail of the ranking).
pub fn build_instance(
    dc: &DataCenter,
    window: &[GpuRef],
    pending: &[VmSpec],
    max_vms: usize,
    weight_of: &dyn Fn(VmId) -> f64,
) -> ExtractedInstance {
    // Shrink the ranked window until its residents fit the VM cap.
    let mut ranked: Vec<GpuRef> = window.to_vec();
    loop {
        let residents: usize = ranked.iter().map(|&r| dc.gpu(r).instances().len()).sum();
        if residents <= max_vms || ranked.len() <= 1 {
            break;
        }
        ranked.pop();
    }
    // Dense indices follow ascending GpuRef (the determinism contract).
    ranked.sort();
    ranked.dedup();

    let mut host_ids: Vec<u32> = ranked.iter().map(|r| r.host).collect();
    host_ids.dedup();
    let map = InstanceMap {
        gpus: host_ids
            .iter()
            .map(|&h| ranked.iter().filter(|r| r.host == h).copied().collect())
            .collect(),
    };

    let mut vms: Vec<VmSpec> = Vec::new();
    let mut prior: HashMap<VmId, PriorPlacement> = HashMap::new();
    // Per-host CPU/RAM the instance VMs currently hold (handed back to
    // the ILP's capacity: residents are re-placeable, so their
    // reservations count as capacity, not as consumption).
    let mut held: Vec<(u64, u64)> = vec![(0, 0); host_ids.len()];
    for (j, host_gpus) in map.gpus.iter().enumerate() {
        for (k, &r) in host_gpus.iter().enumerate() {
            for inst in dc.gpu(r).instances() {
                let (cpus, ram_gb) = dc.vm_demands(inst.vm).unwrap_or((0, 0));
                vms.push(VmSpec {
                    id: inst.vm,
                    profile: inst.placement.profile,
                    cpus,
                    ram_gb,
                    arrival: 0,
                    departure: 0,
                    weight: weight_of(inst.vm),
                });
                prior.insert(
                    inst.vm,
                    PriorPlacement { host: j, gpu: k, delta: inst.placement.profile.size() as f64 },
                );
                held[j].0 += cpus as u64;
                held[j].1 += ram_gb as u64;
            }
        }
    }

    let model = ranked.first().map(|&r| dc.gpu(r).model());
    let mut included_pending = Vec::new();
    for p in pending {
        if vms.len() >= max_vms {
            break;
        }
        if Some(p.profile.model()) != model {
            continue;
        }
        included_pending.push(p.id);
        vms.push(*p);
    }

    let hosts: Vec<IlpHost> = host_ids
        .iter()
        .enumerate()
        .map(|(j, &h)| {
            let host = dc.host(h);
            IlpHost {
                cpus: host.free_cpus().saturating_add(held[j].0.min(u32::MAX as u64) as u32),
                ram_gb: host.free_ram().saturating_add(held[j].1.min(u32::MAX as u64) as u32),
                num_gpus: map.gpus[j].len(),
                weight: 1.0,
            }
        })
        .collect();

    ExtractedInstance { inst: PlacementInstance { hosts, vms, prior }, map, included_pending }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{HealthState, Host};
    use crate::mig::{Placement, Profile};

    fn place(dc: &mut DataCenter, id: u64, profile: Profile, r: GpuRef, start: u8) {
        let vm =
            VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 10, weight: 1.0 };
        dc.place(&vm, r, Placement { profile, start });
    }

    fn pend(id: u64, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 10, weight: 1.0 }
    }

    #[test]
    fn window_ranks_by_fragmentation_then_index() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 3)]);
        // GPU 1: stray 1g at block 4 (fragmented); GPUs 0 and 2 empty.
        place(&mut dc, 1, Profile::P1g5gb, GpuRef { host: 0, gpu: 1 }, 4);
        let w = fragmented_window(&dc, PlanScope::Cluster, crate::mig::GpuModel::A100_40, 2);
        assert_eq!(w[0], GpuRef { host: 0, gpu: 1 }, "fragmented GPU ranks first");
        assert_eq!(w[1], GpuRef { host: 0, gpu: 0 }, "ties fall back to lowest index");
    }

    #[test]
    fn window_skips_unavailable_capacity() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2), Host::new(1, 64, 256, 1)]);
        place(&mut dc, 1, Profile::P1g5gb, GpuRef { host: 0, gpu: 0 }, 4);
        place(&mut dc, 2, Profile::P1g5gb, GpuRef { host: 0, gpu: 1 }, 4);
        place(&mut dc, 3, Profile::P1g5gb, GpuRef { host: 1, gpu: 0 }, 4);
        dc.set_gpu_health(GpuRef { host: 0, gpu: 0 }, HealthState::Draining);
        dc.set_host_health(1, HealthState::Draining);
        let w = fragmented_window(&dc, PlanScope::Cluster, crate::mig::GpuModel::A100_40, 8);
        assert_eq!(w, vec![GpuRef { host: 0, gpu: 1 }], "draining GPU/host must be skipped");
    }

    #[test]
    fn instance_carries_priors_and_capacity() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        place(&mut dc, 1, Profile::P1g5gb, GpuRef { host: 0, gpu: 0 }, 4);
        let w = fragmented_window(&dc, PlanScope::Cluster, crate::mig::GpuModel::A100_40, 2);
        let ex = build_instance(&dc, &w, &[pend(10, Profile::P2g10gb)], MAX_INSTANCE_VMS, &|_| {
            REPAIR_WEIGHT
        });
        assert_eq!(ex.inst.hosts.len(), 1);
        assert_eq!(ex.inst.hosts[0].num_gpus, 2);
        // Host capacity hands the resident's reservation back: 62 free
        // + 2 held.
        assert_eq!(ex.inst.hosts[0].cpus, 64);
        assert_eq!(ex.inst.vms.len(), 2);
        assert_eq!(ex.inst.vms[0].id, 1);
        assert!((ex.inst.vms[0].weight - REPAIR_WEIGHT).abs() < 1e-9);
        assert_eq!(ex.inst.prior.len(), 1);
        assert_eq!(ex.included_pending, vec![10]);
        // Local coordinates round-trip through the map.
        let p = ex.inst.prior[&1];
        assert_eq!(ex.map.gpu(p.host, p.gpu), GpuRef { host: 0, gpu: 0 });
    }

    #[test]
    fn vm_cap_truncates_pending_first() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        place(&mut dc, 1, Profile::P1g5gb, GpuRef { host: 0, gpu: 0 }, 4);
        let w = fragmented_window(&dc, PlanScope::Cluster, crate::mig::GpuModel::A100_40, 1);
        let pending: Vec<VmSpec> = (10..20).map(|i| pend(i, Profile::P1g5gb)).collect();
        let ex = build_instance(&dc, &w, &pending, 3, &|_| REPAIR_WEIGHT);
        assert_eq!(ex.inst.vms.len(), 3, "1 resident + 2 pending under the cap");
        assert_eq!(ex.included_pending, vec![10, 11]);
        assert_eq!(ex.inst.prior.len(), 1, "residents survive the cap");
    }

    #[test]
    fn foreign_model_pending_is_skipped() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        place(&mut dc, 1, Profile::P1g5gb, GpuRef { host: 0, gpu: 0 }, 4);
        let w = fragmented_window(&dc, PlanScope::Cluster, crate::mig::GpuModel::A100_40, 1);
        let a30 = crate::mig::GpuModel::A30.profile(0);
        let ex = build_instance(&dc, &w, &[pend(10, a30)], MAX_INSTANCE_VMS, &|_| 1.0);
        assert!(ex.included_pending.is_empty());
        assert_eq!(ex.inst.vms.len(), 1);
    }
}
