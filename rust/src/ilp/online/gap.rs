//! Online optimality-gap metering.
//!
//! [`GapMeter`] wraps any [`Policy`] and, on a configurable cadence,
//! cross-checks the wrapped policy's admission decisions against the
//! bounded exact solver: before handing a due batch to the inner
//! policy, it extracts the same kind of instance [`RollingIlp`] repairs
//! — the most fragmented `window` GPUs per model plus the batch's
//! requests — but with *true* request weights, solves it under the node
//! budget, and compares the ILP's weighted acceptance against what the
//! policy actually achieved on the same VMs. The relative shortfall is
//! recorded as one `gap%` sample, drained by the engine through
//! [`Policy::drain_gap_samples_into`] into `SimResult::gap_samples` and
//! surfaced in `repro sweep` / `tables::optimality_gap`.
//!
//! ## What the number means
//!
//! The ILP bound is computed over the *extracted window*, not the whole
//! cluster: residents outside the window and placements the policy
//! makes outside it are invisible to the bound. Within the window the
//! bound is exact (the lexicographic optimum under the node budget, and
//! the budget only ever *lowers* the bound, never inflates it), so the
//! sample is a sound per-window gap; because the policy may serve a
//! request from outside the window, an apparent negative gap is clamped
//! to zero. Only when `window` covers the entire fleet of a model is
//! the sample a true cluster-wide optimality gap — the configuration
//! the `ilp_cross_validation` tests run.
//!
//! [`RollingIlp`]: super::RollingIlp

use super::extract::{build_instance, fragmented_window};
use crate::cluster::vm::{Time, VmId, VmSpec, HOUR};
use crate::cluster::DataCenter;
use crate::ilp::{IlpSolver, NodeBudget};
use crate::mig::GpuModel;
use crate::migrate::{MigrationEvent, PlanScope};
use crate::policies::{Policy, PolicyCtx};
use std::collections::{HashMap, HashSet};

/// Policy wrapper sampling the optimality gap on a cadence. See the
/// module docs for the bound's semantics.
pub struct GapMeter {
    inner: Box<dyn Policy>,
    /// Sampling cadence in hours (> 0; a zero-cadence meter is never
    /// built — the registry skips the wrapper).
    every: u64,
    /// Extraction window: most-fragmented GPUs per model.
    window: usize,
    /// Branch-and-bound node budget per solver stage.
    budget: NodeBudget,
    /// Next batch at or after this time is sampled. Starts at 0 so the
    /// first batch of a run is always a sample.
    next_due: Time,
    /// True weights of resident VMs (the cluster stores demands, not
    /// weights). Populated from placed decisions, pruned on departure;
    /// VMs placed before this wrapper saw them default to 1.0.
    weights: HashMap<VmId, f64>,
    samples: Vec<f64>,
}

/// One batch's ILP-side aggregate, accumulated over the per-model
/// instances.
struct Bound {
    /// Sum of ILP weighted acceptances over the sampled instances.
    ilp: f64,
    /// Weight of the window *residents* in those instances — the part
    /// of the achievable value the policy already holds.
    resident: f64,
    /// Batch VMs that made it into some instance; only their outcomes
    /// count against the bound.
    covered: HashSet<VmId>,
}

impl GapMeter {
    pub fn new(inner: Box<dyn Policy>, every: u64, window: usize, node_limit: usize) -> GapMeter {
        GapMeter {
            inner,
            every,
            window,
            budget: NodeBudget::from_limit(node_limit),
            next_due: 0,
            weights: HashMap::new(),
            samples: Vec::new(),
        }
    }

    /// Solve the bounded per-model instances for `vms` against the
    /// *pre-batch* cluster. `None` when nothing was sampleable (no
    /// model landed in an instance) or a solver stage found no
    /// incumbent under the budget — either way, no sample this round.
    fn bound_for_batch(&self, dc: &DataCenter, vms: &[VmSpec]) -> Option<Bound> {
        let mut models: Vec<GpuModel> = vms.iter().map(|v| v.profile.model()).collect();
        models.sort();
        models.dedup();
        let mut bound = Bound { ilp: 0.0, resident: 0.0, covered: HashSet::new() };
        let mut sampled_any = false;
        for model in models {
            let window = fragmented_window(dc, PlanScope::Cluster, model, self.window);
            if window.is_empty() {
                continue;
            }
            let pending: Vec<VmSpec> =
                vms.iter().filter(|v| v.profile.model() == model).copied().collect();
            let weights = &self.weights;
            let ex = build_instance(dc, &window, &pending, super::extract::MAX_INSTANCE_VMS, &|id| {
                weights.get(&id).copied().unwrap_or(1.0)
            });
            if ex.included_pending.is_empty() {
                // The VM cap ate the whole batch share: no admission
                // question is being asked of the ILP for this model.
                continue;
            }
            let sol = IlpSolver::new(ex.inst.clone()).solve_budgeted(self.budget)?;
            bound.ilp += sol.acceptance;
            for vm in &ex.inst.vms {
                if ex.inst.prior.contains_key(&vm.id) {
                    bound.resident += vm.weight;
                }
            }
            bound.covered.extend(ex.included_pending.iter().copied());
            sampled_any = true;
        }
        sampled_any.then_some(bound)
    }
}

impl Policy for GapMeter {
    fn name(&self) -> &str {
        // Transparent: reports and sweep rows keep the wrapped name.
        self.inner.name()
    }

    fn place_batch_into(&mut self, dc: &mut DataCenter, vms: &[VmSpec], ctx: &mut PolicyCtx) {
        let bound = if self.every > 0 && ctx.now >= self.next_due && !vms.is_empty() {
            // Advance the clock even when the bound comes back empty —
            // a failed sample must not make every later batch retry.
            self.next_due = ctx.now + self.every * HOUR;
            self.bound_for_batch(dc, vms)
        } else {
            None
        };
        self.inner.place_batch_into(dc, vms, ctx);
        let mut achieved = 0.0;
        for (vm, d) in vms.iter().zip(ctx.decisions.iter()) {
            if d.is_placed() {
                self.weights.insert(vm.id, vm.weight);
                if bound.as_ref().is_some_and(|b| b.covered.contains(&vm.id)) {
                    achieved += vm.weight;
                }
            }
        }
        if let Some(b) = bound {
            if b.ilp > 1e-9 {
                let gap = (b.ilp - (b.resident + achieved)) / b.ilp * 100.0;
                // The policy may serve covered VMs from *outside* the
                // window; that shows up as beating the window-local
                // bound. Clamp — the bound is only sound within it.
                self.samples.push(gap.max(0.0));
            }
        }
    }

    fn on_departure(&mut self, dc: &mut DataCenter, vm: VmId, ctx: &mut PolicyCtx) {
        self.weights.remove(&vm);
        self.inner.on_departure(dc, vm, ctx);
    }

    fn on_tick(&mut self, dc: &mut DataCenter, ctx: &mut PolicyCtx) {
        self.inner.on_tick(dc, ctx);
    }

    fn drain_migrations_into(&mut self, out: &mut Vec<MigrationEvent>) {
        self.inner.drain_migrations_into(out);
    }

    fn drain_gap_samples_into(&mut self, out: &mut Vec<f64>) {
        out.append(&mut self.samples);
    }

    fn snapshot_state(&self, out: &mut Vec<u8>) {
        let mut e = crate::util::codec::Enc::new();
        let mut inner = Vec::new();
        self.inner.snapshot_state(&mut inner);
        e.blob(&inner);
        e.u64(self.next_due);
        let mut weights: Vec<(VmId, f64)> = self.weights.iter().map(|(&k, &v)| (k, v)).collect();
        weights.sort_by_key(|&(k, _)| k);
        e.usize(weights.len());
        for (vm, w) in weights {
            e.u64(vm);
            e.f64(w);
        }
        e.usize(self.samples.len());
        for &s in &self.samples {
            e.f64(s);
        }
        out.extend_from_slice(e.bytes());
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut d = crate::util::codec::Dec::new(bytes);
        let inner = d.blob()?.to_vec();
        self.inner.restore_state(&inner)?;
        self.next_due = d.u64()?;
        let n = d.count(16)?;
        self.weights = HashMap::with_capacity(n);
        for _ in 0..n {
            let vm = d.u64()?;
            let w = d.f64()?;
            self.weights.insert(vm, w);
        }
        let n = d.count(8)?;
        self.samples = Vec::with_capacity(n);
        for _ in 0..n {
            self.samples.push(d.f64()?);
        }
        if !d.is_empty() {
            return Err("trailing bytes in gap-meter state".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{GpuRef, Host};
    use crate::mig::{Placement, Profile};
    use crate::policies::{PolicyConfig, PolicyRegistry};

    fn vm(id: VmId, profile: Profile, weight: f64) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 1000, weight }
    }

    fn meter(window: usize) -> GapMeter {
        let inner = PolicyRegistry::standard().build("ff", &PolicyConfig::new()).unwrap();
        GapMeter::new(inner, 24, window, 100_000)
    }

    #[test]
    fn optimal_policy_on_empty_cluster_has_zero_gap() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        let mut meter = meter(8);
        let mut ctx = PolicyCtx::new(7);
        let batch = [vm(1, Profile::P1g5gb, 1.0), vm(2, Profile::P2g10gb, 2.0)];
        meter.place_batch_into(&mut dc, &batch, &mut ctx);
        assert!(ctx.decisions.iter().all(|d| d.is_placed()));
        let mut samples = Vec::new();
        meter.drain_gap_samples_into(&mut samples);
        assert_eq!(samples, vec![0.0], "everything placed => no gap");
        // Drain is destructive.
        let mut again = Vec::new();
        meter.drain_gap_samples_into(&mut again);
        assert!(again.is_empty());
    }

    #[test]
    fn cadence_skips_batches_inside_the_period() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        let mut meter = meter(8);
        let mut ctx = PolicyCtx::new(7);
        ctx.now = 0;
        meter.place_batch_into(&mut dc, &[vm(1, Profile::P1g5gb, 1.0)], &mut ctx);
        ctx.now = HOUR; // inside the 24 h period
        meter.place_batch_into(&mut dc, &[vm(2, Profile::P1g5gb, 1.0)], &mut ctx);
        ctx.now = 25 * HOUR; // due again
        meter.place_batch_into(&mut dc, &[vm(3, Profile::P1g5gb, 1.0)], &mut ctx);
        let mut samples = Vec::new();
        meter.drain_gap_samples_into(&mut samples);
        assert_eq!(samples.len(), 2, "hour-1 batch must not be sampled: {samples:?}");
    }

    /// A stray 1g at block 2 makes the 4g.20gb (sole legal start 0)
    /// unplaceable for the policy, but the ILP (which may move
    /// residents) accepts it — a real gap.
    #[test]
    fn fragmentation_shortfall_is_measured() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        let stray = vm(1, Profile::P1g5gb, 1.0);
        dc.place(&stray, GpuRef { host: 0, gpu: 0 }, Placement {
            profile: Profile::P1g5gb,
            start: 2,
        });
        let mut meter = meter(8);
        meter.weights.insert(1, 1.0);
        let mut ctx = PolicyCtx::new(7);
        let batch = [vm(2, Profile::P4g20gb, 3.0)];
        meter.place_batch_into(&mut dc, &batch, &mut ctx);
        assert!(!ctx.decisions[0].is_placed(), "FF cannot place the 4g past the stray");
        let mut samples = Vec::new();
        meter.drain_gap_samples_into(&mut samples);
        assert_eq!(samples.len(), 1);
        // ILP bound: stray (1.0) + 4g (3.0) = 4.0; achieved: 1.0.
        assert!((samples[0] - 75.0).abs() < 1e-6, "gap was {}", samples[0]);
    }

    #[test]
    fn registry_wraps_when_gap_check_enabled() {
        let registry = PolicyRegistry::standard();
        let cfg = PolicyConfig::new().gap_check_hours(24);
        let mut p = registry.build("mcc+ilp-repair", &cfg).unwrap();
        assert_eq!(p.name(), "MCC+ilp-repair", "the meter must not rename the policy");
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        let mut ctx = PolicyCtx::new(7);
        p.place_batch_into(&mut dc, &[vm(1, Profile::P1g5gb, 1.0)], &mut ctx);
        let mut samples = Vec::new();
        p.drain_gap_samples_into(&mut samples);
        assert_eq!(samples.len(), 1, "wrapped policy must sample through the trait");
        // Without the knob the policy is not wrapped: no samples.
        let mut bare = registry.build("mcc", &PolicyConfig::new()).unwrap();
        let mut dc2 = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        let mut ctx2 = PolicyCtx::new(7);
        bare.place_batch_into(&mut dc2, &[vm(1, Profile::P1g5gb, 1.0)], &mut ctx2);
        let mut none = Vec::new();
        bare.drain_gap_samples_into(&mut none);
        assert!(none.is_empty());
    }
}
