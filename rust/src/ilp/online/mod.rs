//! Rolling-horizon ILP repair: the paper's Eq. 3–26 formulation, solved
//! *online* over bounded windows of the live cluster.
//!
//! §7 shows the full-fleet ILP is intractable, and the offline
//! [`IlpSolver`] is only used as ground truth on synthetic shapes. This
//! module closes the loop the way IBM's MIG workload-placement study
//! does with bounded exact repair: on a configurable cadence (and on
//! rejection bursts), [`RollingIlp`] extracts the most fragmented `K`
//! GPUs per model — plus the interval's pending rejects — as a
//! [`PlacementInstance`] ([`extract`]), solves it lexicographically
//! (acceptance ≻ active hardware ≻ migration cost) under a
//! deterministic branch-and-bound node budget
//! ([`IlpSolver::solve_budgeted`]), and translates the solution into a
//! transactional [`MigrationPlan`] applied through
//! [`DataCenter::apply_plan`](crate::cluster::DataCenter::apply_plan).
//!
//! The planner registers as `"ilp-repair"` in
//! `policies::planned::planner_from_name`, so any base policy composes
//! through the registry: `mcc+ilp-repair`, `ff+ilp-repair`, ...
//!
//! [`GapMeter`] (in [`gap`]) reuses the same extraction with *true*
//! request weights to report a per-policy optimality gap: how much
//! weighted acceptance the policy left on the table versus the bounded
//! ILP bound, sampled on a cadence and surfaced as `gap%` in
//! `SimResult` / `repro sweep` / `tables::optimality_gap`.
//!
//! ## Determinism
//!
//! The whole pipeline is a pure function of cluster state and
//! configuration: extraction orders hosts/GPUs/VMs by ascending
//! [`GpuRef`] (see [`extract`]'s contract), the branch-and-bound is
//! deterministic under its node limit (see `ilp::bb`), and translation
//! walks destinations in ascending `GpuRef`. The budget is a *node*
//! budget only — a wall-clock deadline would make plans depend on
//! machine load and break byte-reproducibility, so there isn't one.
//!
//! ## What a repair plan can and cannot do
//!
//! A [`MigrationPlan`] moves *resident* VMs; pending rejects cannot be
//! placed by a plan. Rejects instead enter the ILP as demand
//! ([`PlanCtx::pending`]): the solver lays the window out so that the
//! rejected profiles *would* fit, and the plan realizes that layout —
//! freeing contiguous space the admission queue's retries or future
//! arrivals of the same shape can use. Prior VMs carry
//! [`extract::REPAIR_WEIGHT`], so repair never trades a resident away
//! for pending demand (plans relocate, they never evict).

pub mod extract;
pub mod gap;

pub use extract::{
    build_instance, fragmented_window, ExtractedInstance, InstanceMap, MAX_INSTANCE_VMS,
    REPAIR_WEIGHT,
};
pub use gap::GapMeter;

use crate::cluster::vm::{Time, VmId, HOUR};
use crate::cluster::{DataCenter, GpuRef};
use crate::ilp::model::{PlacementInstance, PlacementSolution};
use crate::ilp::{IlpSolver, NodeBudget};
use crate::mig::fragmentation::fragmentation_value;
use crate::mig::{BlockMask, GpuModel, Instance, Placement};
use crate::migrate::{MigrationPlan, MigrationPlanner, PlanCtx, PlanTrigger, PlanView};
use std::collections::BTreeMap;

/// The rolling-horizon ILP repair planner. See the module docs.
#[derive(Debug, Clone)]
pub struct RollingIlp {
    /// GPUs per model in the extraction window. `0` disables the
    /// planner entirely.
    window: usize,
    /// Branch-and-bound node budget per solver stage. `0` disables the
    /// planner entirely (note the divergence from [`crate::ilp::Milp`],
    /// where 0 means *unlimited* — an online planner must never run
    /// unbounded, so the zero is claimed for "off" and guarded before
    /// the solver is ever called).
    node_limit: usize,
    /// Tick cadence in hours (rejection bursts plan regardless).
    period_hours: u64,
    /// `now` of the last tick-triggered round.
    last_tick_run: Option<Time>,
}

impl RollingIlp {
    pub fn new(window: usize, node_limit: usize, period_hours: u64) -> RollingIlp {
        RollingIlp { window, node_limit, period_hours, last_tick_run: None }
    }
}

impl MigrationPlanner for RollingIlp {
    fn name(&self) -> &'static str {
        "ilp-repair"
    }

    fn plan(&mut self, dc: &DataCenter, ctx: &PlanCtx, plan: &mut MigrationPlan) {
        if self.window == 0 || self.node_limit == 0 {
            // Disabled: byte-identical to the planner-free variant
            // (locked in rust/tests/decision_api.rs).
            return;
        }
        match ctx.trigger {
            // A rejection burst plans immediately — but only when the
            // caller actually handed the rejects over; a bare rejection
            // signal carries no demand to lay out.
            PlanTrigger::Rejection => {
                if ctx.pending.is_empty() {
                    return;
                }
            }
            PlanTrigger::Tick => {
                let period = self.period_hours.saturating_mul(HOUR);
                if let Some(last) = self.last_tick_run {
                    if ctx.now < last.saturating_add(period) {
                        return;
                    }
                }
                self.last_tick_run = Some(ctx.now);
            }
        }
        // One bounded instance per model (the ILP host row carries no
        // model, so instances are single-model by construction), in
        // catalog order.
        let mut models: Vec<GpuModel> = Vec::new();
        for r in ctx.scope.gpus(dc) {
            if !dc.gpu_available(r) {
                continue;
            }
            let m = dc.gpu(r).model();
            if !models.contains(&m) {
                models.push(m);
            }
        }
        models.sort();
        for model in models {
            let window = fragmented_window(dc, ctx.scope, model, self.window);
            if window.is_empty() {
                continue;
            }
            let pending: Vec<_> =
                ctx.pending.iter().filter(|v| v.profile.model() == model).copied().collect();
            let fragmented = window
                .iter()
                .any(|&r| fragmentation_value(model, dc.gpu(r).occupancy()) > 0.0);
            if pending.is_empty() && !fragmented {
                // Nothing to repair and no demand to lay out for.
                continue;
            }
            let ex = build_instance(dc, &window, &pending, MAX_INSTANCE_VMS, &|_| REPAIR_WEIGHT);
            if ex.inst.vms.is_empty() {
                continue;
            }
            let solver = IlpSolver::new(ex.inst.clone());
            // node_limit > 0 here (0 = disabled, guarded above), so name
            // the bounded variant explicitly — `from_limit`'s 0 ⇒
            // Unlimited mapping must never apply to an online planner.
            let Some(sol) = solver.solve_budgeted(NodeBudget::Nodes(self.node_limit as u64)) else {
                continue;
            };
            translate_into_plan(dc, &ex.inst, &ex.map, &sol, plan);
        }
    }

    fn snapshot_state(&self, out: &mut Vec<u8>) {
        let mut e = crate::util::codec::Enc::new();
        e.opt_u64(self.last_tick_run);
        out.extend_from_slice(e.bytes());
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut d = crate::util::codec::Dec::new(bytes);
        self.last_tick_run = d.opt_u64()?;
        if !d.is_empty() {
            return Err("trailing bytes in ilp-repair state".into());
        }
        Ok(())
    }
}

/// One destination GPU's share of an ILP solution.
#[derive(Default)]
struct DestGroup {
    /// Residents of this GPU assigned to stay on it, with their ILP
    /// placements. Unassigned residents (possible only under truncated
    /// budgets) appear with their *current* placement and taint the
    /// group.
    stay: Vec<(Instance, Placement)>,
    /// `(vm, from, old placement, new placement)` of VMs moving in.
    incoming: Vec<(VmId, GpuRef, Placement, Placement)>,
    /// Union of the blocks the ILP assigned to *pending* VMs on this
    /// GPU. A plan cannot place them, but the layout it realizes must
    /// keep these blocks free — that reservation is the entire point of
    /// a demand-driven repair.
    pending_mask: BlockMask,
    /// Some resident had no ILP assignment: the ILP's layout for this
    /// GPU is incomplete, so the repack fallback is off the table.
    tainted: bool,
}

/// Translate an ILP solution over an extracted instance into plan
/// steps, validated against a [`PlanView`] overlay so the transactional
/// apply never rolls back.
///
/// Per destination GPU the cheap layout is preferred: keep stayers at
/// their current blocks (same-GPU start changes carry no cost in the
/// model — [`crate::ilp::model::PriorPlacement`] has no start) and only
/// move the incoming VMs. When the incoming placements collide with a
/// stayer's current blocks, the GPU falls back to the ILP's full layout
/// — one atomic `Repack` of the stayers plus the incoming `Migrate`s.
/// Steps are then emitted in deterministic greedy rounds over the
/// `PlanView` (repacks by ascending GPU, then migrates), so chains
/// ("A's blocks free once B leaves") resolve and genuine cycles are
/// dropped rather than planned infeasibly.
pub(crate) fn translate_into_plan(
    dc: &DataCenter,
    inst: &PlacementInstance,
    map: &InstanceMap,
    sol: &PlacementSolution,
    plan: &mut MigrationPlan,
) {
    let mut groups: BTreeMap<GpuRef, DestGroup> = BTreeMap::new();
    for vm in &inst.vms {
        if !inst.prior.contains_key(&vm.id) {
            // Pending demand: not movable, but its assigned blocks are
            // reserved in the layout the plan realizes.
            if let Some(&(j, k, start)) = sol.assignment.get(&vm.id) {
                let mask = Placement { profile: vm.profile, start }.mask();
                groups.entry(map.gpu(j, k)).or_default().pending_mask |= mask;
            }
            continue;
        }
        let Some(loc) = dc.locate(vm.id) else { continue };
        match sol.assignment.get(&vm.id) {
            Some(&(j, k, start)) => {
                let dest = map.gpu(j, k);
                let new = Placement { profile: vm.profile, start };
                if dest == loc.gpu {
                    let live = Instance { vm: vm.id, placement: loc.placement };
                    groups.entry(dest).or_default().stay.push((live, new));
                } else {
                    groups.entry(dest).or_default().incoming.push((
                        vm.id,
                        loc.gpu,
                        loc.placement,
                        new,
                    ));
                }
            }
            None => {
                // Only a truncated solve drops a REPAIR_WEIGHT prior;
                // leave the VM where it is and taint its GPU.
                let live = Instance { vm: vm.id, placement: loc.placement };
                let g = groups.entry(loc.gpu).or_default();
                g.stay.push((live, loc.placement));
                g.tainted = true;
            }
        }
    }

    enum Step {
        Repack { gpu: GpuRef, moves: Vec<(Instance, Placement)> },
        Migrate {
            vm: VmId,
            from: GpuRef,
            old: Placement,
            to: GpuRef,
            new: Placement,
            cpus: u32,
            ram_gb: u32,
        },
    }

    let mut repacks: Vec<Step> = Vec::new();
    let mut migrates: Vec<Step> = Vec::new();
    for (&dest, group) in &groups {
        if group.incoming.is_empty() && group.stay.iter().all(|(i, n)| i.placement == *n) {
            // Nothing moves here. Pending reservations need no action
            // either: the ILP placed them against these same stay
            // positions, so the blocks are already free.
            continue;
        }
        let stay_cur: BlockMask = group.stay.iter().fold(0, |m, (i, _)| m | i.placement.mask());
        let moving_out: BlockMask = dc
            .gpu(dest)
            .instances()
            .iter()
            .filter(|i| inst.prior.contains_key(&i.vm))
            .filter(|i| !group.stay.iter().any(|(s, _)| s.vm == i.vm))
            .fold(0, |m, i| m | i.placement.mask());
        // Blocks held by VMs outside the instance (none on a window
        // GPU, but translation must not assume that).
        let extraneous = dc.gpu(dest).occupancy() & !stay_cur & !moving_out;

        // Layout A: stayers keep their current blocks; only incoming
        // VMs move. Feasible when the pending reservations and the
        // incoming ILP placements avoid the stayers' *current* blocks
        // (and each other, and any non-instance resident).
        let mut occ_a = stay_cur | extraneous;
        let layout_a_ok = occ_a & group.pending_mask == 0 && {
            occ_a |= group.pending_mask;
            group.incoming.iter().all(|(_, _, _, new)| {
                if occ_a & new.mask() != 0 {
                    return false;
                }
                occ_a |= new.mask();
                true
            })
        };
        if layout_a_ok {
            for &(vm, from, old, new) in &group.incoming {
                let (cpus, ram_gb) = dc.vm_demands(vm).unwrap_or((0, 0));
                migrates.push(Step::Migrate { vm, from, old, to: dest, new, cpus, ram_gb });
            }
            continue;
        }
        // Layout B: adopt the ILP's layout wholesale — repack the
        // stayers, then the incoming placements fit by the solver's
        // non-overlap constraints. Requires a complete layout (not
        // tainted) and no extraneous residents in the way.
        if group.tainted || extraneous & group.pending_mask != 0 {
            continue;
        }
        let mut occ_b = extraneous | group.pending_mask;
        let layout_b_ok = group
            .stay
            .iter()
            .map(|(_, new)| new)
            .chain(group.incoming.iter().map(|(_, _, _, new)| new))
            .all(|new| {
                if occ_b & new.mask() != 0 {
                    return false;
                }
                occ_b |= new.mask();
                true
            });
        if !layout_b_ok {
            continue;
        }
        let moves: Vec<(Instance, Placement)> = group
            .stay
            .iter()
            .filter(|(i, n)| i.placement != *n)
            .cloned()
            .collect();
        if !moves.is_empty() {
            repacks.push(Step::Repack { gpu: dest, moves });
        }
        for &(vm, from, old, new) in &group.incoming {
            let (cpus, ram_gb) = dc.vm_demands(vm).unwrap_or((0, 0));
            migrates.push(Step::Migrate { vm, from, old, to: dest, new, cpus, ram_gb });
        }
    }

    // Greedy feasibility rounds over a PlanView: emit every step that
    // validates against the virtual state, repeat until a full pass
    // adds nothing (chains resolve across rounds; cycles are dropped).
    let mut steps = repacks;
    steps.append(&mut migrates);
    let mut emitted = vec![false; steps.len()];
    let mut view = PlanView::new(dc);
    loop {
        let mut progressed = false;
        for i in 0..steps.len() {
            if emitted[i] {
                continue;
            }
            let feasible = match &steps[i] {
                Step::Repack { gpu, moves } => {
                    let freed: BlockMask =
                        moves.iter().fold(0, |m, (inst, _)| m | inst.placement.mask());
                    let mut occ = view.occupancy(*gpu) & !freed;
                    moves.iter().all(|(_, new)| {
                        if occ & new.mask() != 0 {
                            return false;
                        }
                        occ |= new.mask();
                        true
                    })
                }
                Step::Migrate { from, to, new, cpus, ram_gb, .. } => {
                    view.occupancy(*to) & new.mask() == 0
                        && (from.host == to.host || view.host_fits(to.host, *cpus, *ram_gb))
                }
            };
            if !feasible {
                continue;
            }
            match &steps[i] {
                Step::Repack { gpu, moves } => {
                    for (inst, new) in moves {
                        view.note_move(*gpu, inst.placement, *gpu, *new, 0, 0);
                    }
                    plan.push_repack(*gpu, moves.clone());
                }
                Step::Migrate { vm, from, old, to, new, cpus, ram_gb } => {
                    view.note_move(*from, *old, *to, *new, *cpus, *ram_gb);
                    plan.push_migrate(*vm, *from, *to, *new);
                }
            }
            emitted[i] = true;
            progressed = true;
        }
        if !progressed {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::vm::VmSpec;
    use crate::cluster::Host;
    use crate::mig::Profile;
    use crate::migrate::{MigrationBudget, PlanScope, PlannerStack};

    fn place(dc: &mut DataCenter, id: u64, profile: Profile, r: GpuRef, start: u8) {
        let vm =
            VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 10, weight: 1.0 };
        dc.place(&vm, r, Placement { profile, start });
    }

    fn pend(id: u64, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 10, weight: 1.0 }
    }

    /// The §7.1 shape: a stray 1g inside blocks 0–3 blocks the 4g.20gb
    /// (whose only legal start is 0); the ILP repair relocates the
    /// stray into the upper half so the pending 4g's layout exists.
    #[test]
    fn repairs_the_stray_instance_for_pending_demand() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        let g = GpuRef { host: 0, gpu: 0 };
        place(&mut dc, 1, Profile::P1g5gb, g, 2);
        let mut planner = RollingIlp::new(8, 50_000, 24);
        let mut plan = MigrationPlan::new();
        let pending = [pend(10, Profile::P4g20gb)];
        let ctx = PlanCtx {
            now: 0,
            trigger: PlanTrigger::Rejection,
            scope: PlanScope::Cluster,
            pending: &pending,
        };
        planner.plan(&dc, &ctx, &mut plan);
        assert!(!plan.is_empty(), "repair must relocate the stray 1g");
        dc.apply_plan(&plan).unwrap();
        dc.check_integrity().unwrap();
        // The 4g.20gb now fits: blocks 0..4 are contiguous and free.
        assert_eq!(dc.gpu(g).occupancy() & 0b0000_1111, 0, "{:08b}", dc.gpu(g).occupancy());
    }

    #[test]
    fn zero_window_or_zero_nodes_is_a_no_op() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        place(&mut dc, 1, Profile::P1g5gb, GpuRef { host: 0, gpu: 0 }, 2);
        let pending = [pend(10, Profile::P4g20gb)];
        for (w, n) in [(0usize, 50_000usize), (8, 0), (0, 0)] {
            let mut planner = RollingIlp::new(w, n, 24);
            let mut plan = MigrationPlan::new();
            let ctx = PlanCtx {
                now: 0,
                trigger: PlanTrigger::Rejection,
                scope: PlanScope::Cluster,
                pending: &pending,
            };
            planner.plan(&dc, &ctx, &mut plan);
            assert!(plan.is_empty(), "window={w} nodes={n} must disable the planner");
        }
    }

    #[test]
    fn tick_cadence_gates_periodic_runs() {
        // Two half-used GPUs: the tick-driven round consolidates onto
        // one (the active-hardware objective), the cadence silences the
        // next 24 h even as the cluster re-fragments.
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        let g0 = GpuRef { host: 0, gpu: 0 };
        let g1 = GpuRef { host: 0, gpu: 1 };
        place(&mut dc, 1, Profile::P1g5gb, g0, 0);
        place(&mut dc, 2, Profile::P1g5gb, g1, 0);
        let mut planner = RollingIlp::new(8, 50_000, 24);
        let tick = |planner: &mut RollingIlp, dc: &DataCenter, now: Time| {
            let mut plan = MigrationPlan::new();
            let ctx = PlanCtx {
                now,
                trigger: PlanTrigger::Tick,
                scope: PlanScope::Cluster,
                pending: &[],
            };
            planner.plan(dc, &ctx, &mut plan);
            plan
        };
        // Hour 1: first tick runs and consolidates onto one GPU.
        let p1 = tick(&mut planner, &dc, HOUR);
        assert!(!p1.is_empty(), "first tick should consolidate the two strays");
        dc.apply_plan(&p1).unwrap();
        let emptied = if dc.gpu(g0).is_empty() { g0 } else { g1 };
        assert!(dc.gpu(emptied).is_empty(), "one GPU should have been vacated");
        // Hour 2: inside the 24 h period — silent even when the fleet
        // fragments again.
        place(&mut dc, 3, Profile::P1g5gb, emptied, 0);
        assert!(tick(&mut planner, &dc, 2 * HOUR).is_empty(), "period not yet elapsed");
        // Hour 25: due again.
        assert!(!tick(&mut planner, &dc, 25 * HOUR).is_empty());
    }

    #[test]
    fn planner_runs_are_deterministic() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        place(&mut dc, 1, Profile::P1g5gb, GpuRef { host: 0, gpu: 0 }, 4);
        place(&mut dc, 2, Profile::P2g10gb, GpuRef { host: 0, gpu: 1 }, 2);
        place(&mut dc, 3, Profile::P1g5gb, GpuRef { host: 0, gpu: 1 }, 6);
        let pending = [pend(10, Profile::P4g20gb), pend(11, Profile::P2g10gb)];
        let run = || {
            let mut planner = RollingIlp::new(8, 5_000, 24);
            let mut plan = MigrationPlan::new();
            let ctx = PlanCtx {
                now: 0,
                trigger: PlanTrigger::Rejection,
                scope: PlanScope::Cluster,
                pending: &pending,
            };
            planner.plan(&dc, &ctx, &mut plan);
            plan
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same state + same budget must plan byte-identically");
    }

    /// The plan a `RollingIlp` round produces must apply without the
    /// stack's rollback path ever firing — the PlanView greedy rounds
    /// are exactly the validation `apply_plan` re-runs.
    #[test]
    fn stack_applies_ilp_plans_transactionally() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        place(&mut dc, 1, Profile::P1g5gb, GpuRef { host: 0, gpu: 0 }, 4);
        place(&mut dc, 2, Profile::P1g5gb, GpuRef { host: 0, gpu: 1 }, 2);
        let mut stack = PlannerStack::new(MigrationBudget::unlimited())
            .with(Box::new(RollingIlp::new(8, 50_000, 24)));
        let mut events = Vec::new();
        let pending = [pend(10, Profile::P4g20gb)];
        let n = stack.run_with_pending(
            &mut dc,
            HOUR,
            PlanTrigger::Rejection,
            PlanScope::Cluster,
            &pending,
            &mut events,
        );
        assert_eq!(n as usize, events.len());
        dc.check_integrity().unwrap();
    }

    #[test]
    fn planner_ignores_unavailable_gpus() {
        use crate::cluster::HealthState;
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        place(&mut dc, 1, Profile::P1g5gb, GpuRef { host: 0, gpu: 0 }, 4);
        dc.set_gpu_health(GpuRef { host: 0, gpu: 0 }, HealthState::Draining);
        dc.set_gpu_health(GpuRef { host: 0, gpu: 1 }, HealthState::Failed { until: 100 });
        let mut planner = RollingIlp::new(8, 50_000, 24);
        let mut plan = MigrationPlan::new();
        let pending = [pend(10, Profile::P4g20gb)];
        let ctx = PlanCtx {
            now: 0,
            trigger: PlanTrigger::Rejection,
            scope: PlanScope::Cluster,
            pending: &pending,
        };
        planner.plan(&dc, &ctx, &mut plan);
        assert!(plan.is_empty(), "no schedulable GPU may be planned against");
    }
}
