//! Branch-and-bound MILP on top of the dense simplex.
//!
//! Depth-first search with incumbent pruning; branching on the most
//! fractional integer variable; variable bounds expressed as extra rows
//! appended to the relaxation. Exact for the small instances used to
//! validate the placement heuristics.
//!
//! # Determinism contract
//!
//! The search is a pure function of the `Milp` description and the
//! [`NodeBudget`]: the DFS order, the relaxation pivots and the
//! branching choice involve no randomness, no wall clock and no thread
//! scheduling, so repeated `solve_with(budget)` calls — including
//! truncated ones that return the incumbent at the cap — are
//! byte-identical. Branching ties break
//! toward the **lowest variable index**: the selection key is
//! `(priority class, -fractionality)` compared strictly, so a later
//! variable only wins with a strictly better key. Callers that build
//! MILPs from cluster state (the online ILP planner) therefore get
//! reproducible plans as long as they order variables deterministically
//! (ascending `GpuRef` / dense `ProfileKey` — see `ilp::online`).

use super::lp::{LinearProgram, LpOutcome};

/// Constraint comparison operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

/// A mixed-integer linear program. `maximize` selects the direction.
#[derive(Debug, Clone, Default)]
pub struct Milp {
    pub num_vars: usize,
    pub objective: Vec<f64>,
    pub maximize: bool,
    /// `(sparse coefficients, cmp, rhs)`.
    pub constraints: Vec<(Vec<(usize, f64)>, Cmp, f64)>,
    /// Marks integer variables.
    pub integer: Vec<bool>,
    /// Inclusive variable bounds (defaults `[0, +inf)`).
    pub bounds: Vec<(f64, f64)>,
    /// Branching priority per variable — lower classes branch first.
    /// The placement model puts binaries at 0, `β` at 1 and the
    /// big-M-slack `z` variables at 2: a fractional `z` whose GI is not
    /// even placed is meaningless to branch on and explodes the tree.
    pub branch_priority: Vec<u8>,
    /// When every feasible objective value is integral (integer
    /// coefficients on integer variables), a node whose LP bound is below
    /// `incumbent + 1` cannot contain a strictly better solution — the
    /// pruning gap becomes 1 instead of ε, which is what makes the loose
    /// big-M relaxations of Eq. 12–18 tractable.
    pub integral_objective: bool,
}

/// An optimal MILP solution.
#[derive(Debug, Clone, PartialEq)]
pub struct MilpSolution {
    pub values: Vec<f64>,
    pub objective: f64,
    /// Number of branch-and-bound nodes explored.
    pub nodes: usize,
}

/// Branch-and-bound search budget.
///
/// Replaces the legacy `solve(0)` sentinel, where a literal `0` meant
/// "no cap" rather than "no nodes" — an ambiguity that read exactly
/// backwards at call sites. [`Milp::solve_with`] takes this enum;
/// the sentinel signature survives as a deprecated shim.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NodeBudget {
    /// Search to proven optimality.
    #[default]
    Unlimited,
    /// Explore at most this many nodes; hitting the cap returns the
    /// incumbent found so far, if any.
    Nodes(u64),
}

impl NodeBudget {
    /// Budget from the legacy sentinel encoding (`0` = unlimited).
    pub fn from_limit(limit: usize) -> NodeBudget {
        if limit == 0 {
            NodeBudget::Unlimited
        } else {
            NodeBudget::Nodes(limit as u64)
        }
    }

    /// True once `nodes` explored nodes exceed the budget.
    pub fn exhausted(self, nodes: usize) -> bool {
        match self {
            NodeBudget::Unlimited => false,
            NodeBudget::Nodes(cap) => nodes as u64 > cap,
        }
    }
}

const INT_TOL: f64 = 1e-6;

impl Milp {
    pub fn new(num_vars: usize, objective: Vec<f64>, maximize: bool) -> Milp {
        assert_eq!(objective.len(), num_vars);
        Milp {
            num_vars,
            objective,
            maximize,
            constraints: Vec::new(),
            integer: vec![false; num_vars],
            bounds: vec![(0.0, f64::INFINITY); num_vars],
            branch_priority: vec![0; num_vars],
            integral_objective: false,
        }
    }

    pub fn constrain(&mut self, coeffs: Vec<(usize, f64)>, cmp: Cmp, rhs: f64) {
        self.constraints.push((coeffs, cmp, rhs));
    }

    /// Mark a variable binary (`{0, 1}`).
    pub fn set_binary(&mut self, var: usize) {
        self.integer[var] = true;
        self.bounds[var] = (0.0, 1.0);
    }

    /// Mark a variable integer in `[lo, hi]`.
    pub fn set_integer(&mut self, var: usize, lo: f64, hi: f64) {
        self.integer[var] = true;
        self.bounds[var] = (lo, hi);
    }

    /// Solve under the legacy sentinel encoding (`0` = unlimited).
    #[deprecated(
        since = "0.2.0",
        note = "use solve_with(NodeBudget) — the `0 = unlimited` sentinel is ambiguous"
    )]
    pub fn solve(&self, node_limit: usize) -> Option<MilpSolution> {
        self.solve_with(NodeBudget::from_limit(node_limit))
    }

    /// Solve to proven optimality, or up to the node budget. Returns
    /// `None` when infeasible (or when the budget ran out before any
    /// incumbent); a truncated search returns the incumbent found so
    /// far.
    pub fn solve_with(&self, budget: NodeBudget) -> Option<MilpSolution> {
        // Internal form: maximize. For minimization negate the objective.
        let sign = if self.maximize { 1.0 } else { -1.0 };
        let base_obj: Vec<f64> = self.objective.iter().map(|c| c * sign).collect();

        // Stack of extra bound constraints: (var, is_upper, value).
        let mut stack: Vec<Vec<(usize, bool, f64)>> = vec![Vec::new()];
        let mut incumbent: Option<(Vec<f64>, f64)> = None;
        let mut nodes = 0usize;

        let debug = std::env::var("GRMU_ILP_DEBUG").is_ok();
        while let Some(extra) = stack.pop() {
            nodes += 1;
            if budget.exhausted(nodes) {
                break;
            }
            if debug && nodes % 200 == 0 {
                eprintln!(
                    "[bb] nodes={nodes} stack={} incumbent={:?} depth={}",
                    stack.len(),
                    incumbent.as_ref().map(|(_, b)| *b),
                    extra.len()
                );
            }
            let outcome = self.solve_relaxation(&base_obj, &extra);
            let LpOutcome::Optimal { x, objective } = outcome else {
                continue; // infeasible or (bounded vars) never unbounded
            };
            // Prune by bound (gap 1 for integral objectives).
            let prune_gap = if self.integral_objective { 1.0 - 1e-6 } else { INT_TOL };
            if let Some((_, best)) = &incumbent {
                if objective < *best + prune_gap {
                    continue;
                }
            }
            // Find the most fractional integer variable in the lowest
            // (most important) fractional priority class. Strict `<` on
            // the (class, -fractionality) key means exact ties keep the
            // earlier candidate — the lowest-index tie-break the
            // determinism contract above promises.
            let mut branch: Option<(usize, f64)> = None;
            let mut best: Option<(u8, f64)> = None; // (class, -fractionality)
            for (v, &is_int) in self.integer.iter().enumerate() {
                if !is_int {
                    continue;
                }
                let frac = (x[v] - x[v].round()).abs();
                if frac <= INT_TOL {
                    continue;
                }
                let key = (self.branch_priority[v], -frac);
                if best.map(|b| key < b).unwrap_or(true) {
                    best = Some(key);
                    branch = Some((v, x[v]));
                }
            }
            match branch {
                None => {
                    // Integral: new incumbent.
                    let rounded: Vec<f64> = x
                        .iter()
                        .enumerate()
                        .map(|(v, &val)| if self.integer[v] { val.round() } else { val })
                        .collect();
                    if incumbent.as_ref().map(|(_, b)| objective > *b).unwrap_or(true) {
                        incumbent = Some((rounded, objective));
                    }
                }
                Some((v, val)) => {
                    // Branch: x_v ≤ floor, x_v ≥ ceil. Explore the side
                    // closer to the LP value first (pushed last).
                    let mut lo_branch = extra.clone();
                    lo_branch.push((v, true, val.floor()));
                    let mut hi_branch = extra.clone();
                    hi_branch.push((v, false, val.ceil()));
                    if val - val.floor() < 0.5 {
                        stack.push(hi_branch);
                        stack.push(lo_branch);
                    } else {
                        stack.push(lo_branch);
                        stack.push(hi_branch);
                    }
                }
            }
        }

        incumbent.map(|(values, obj)| MilpSolution { values, objective: obj * sign, nodes })
    }

    fn solve_relaxation(&self, obj: &[f64], extra: &[(usize, bool, f64)]) -> LpOutcome {
        let mut lp = LinearProgram::new(self.num_vars, obj.to_vec());
        for (coeffs, cmp, rhs) in &self.constraints {
            match cmp {
                Cmp::Le => lp.add_le(coeffs, *rhs),
                Cmp::Ge => lp.add_ge(coeffs, *rhs),
                Cmp::Eq => lp.add_eq(coeffs, *rhs),
            }
        }
        for (v, (lo, hi)) in self.bounds.iter().enumerate() {
            if *lo > 0.0 {
                lp.add_ge(&[(v, 1.0)], *lo);
            }
            if hi.is_finite() {
                lp.add_le(&[(v, 1.0)], *hi);
            }
        }
        for &(v, is_upper, val) in extra {
            if is_upper {
                lp.add_le(&[(v, 1.0)], val);
            } else {
                lp.add_ge(&[(v, 1.0)], val);
            }
        }
        lp.solve()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knapsack_small() {
        // max 60a + 100b + 120c, 10a + 20b + 30c ≤ 50, binary → b+c = 220.
        let mut m = Milp::new(3, vec![60.0, 100.0, 120.0], true);
        m.constrain(vec![(0, 10.0), (1, 20.0), (2, 30.0)], Cmp::Le, 50.0);
        for v in 0..3 {
            m.set_binary(v);
        }
        let s = m.solve_with(NodeBudget::Unlimited).unwrap();
        assert!((s.objective - 220.0).abs() < 1e-6);
        assert_eq!(s.values.iter().map(|&v| v.round() as i32).collect::<Vec<_>>(), vec![0, 1, 1]);
    }

    #[test]
    fn integer_rounding_matters() {
        // max x + y, 2x + 2y ≤ 5, integer → 2 (LP gives 2.5).
        let mut m = Milp::new(2, vec![1.0, 1.0], true);
        m.constrain(vec![(0, 2.0), (1, 2.0)], Cmp::Le, 5.0);
        m.set_integer(0, 0.0, 10.0);
        m.set_integer(1, 0.0, 10.0);
        let s = m.solve_with(NodeBudget::Unlimited).unwrap();
        assert!((s.objective - 2.0).abs() < 1e-6);
    }

    #[test]
    fn minimization() {
        // min 3x + 4y s.t. x + 2y ≥ 3, binary... x,y ∈ {0,1,2}: need
        // x + 2y ≥ 3 → best (1,1): 7.
        let mut m = Milp::new(2, vec![3.0, 4.0], false);
        m.constrain(vec![(0, 1.0), (1, 2.0)], Cmp::Ge, 3.0);
        m.set_integer(0, 0.0, 2.0);
        m.set_integer(1, 0.0, 2.0);
        let s = m.solve_with(NodeBudget::Unlimited).unwrap();
        assert!((s.objective - 7.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_returns_none() {
        let mut m = Milp::new(1, vec![1.0], true);
        m.constrain(vec![(0, 1.0)], Cmp::Ge, 2.0);
        m.constrain(vec![(0, 1.0)], Cmp::Le, 1.0);
        m.set_binary(0);
        assert!(m.solve_with(NodeBudget::Unlimited).is_none());
    }

    #[test]
    fn equality_and_mixed_integrality() {
        // max 2x + y, x + y = 3, x integer, y continuous ≤ 1.5 →
        // y ≤ 1.5 → x ≥ 1.5 → x ∈ {2, 3}; x=2, y=1 → 5; x=3, y=0 → 6.
        let mut m = Milp::new(2, vec![2.0, 1.0], true);
        m.constrain(vec![(0, 1.0), (1, 1.0)], Cmp::Eq, 3.0);
        m.set_integer(0, 0.0, 5.0);
        m.bounds[1] = (0.0, 1.5);
        let s = m.solve_with(NodeBudget::Unlimited).unwrap();
        assert!((s.objective - 6.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn bigm_indicator_pattern() {
        // The Eq. 12–13 pattern: two intervals must not overlap.
        // z1, z2 ∈ [0, 6] integer, sizes 4 and 4, B = 8, alpha binary:
        // z1 + 4 ≤ z2 + 8α ; z2 + 4 ≤ z1 + 8(1-α); z1,z2 ∈ {0,4}.
        // maximize z1 + z2 → one at 0, other at 4 → 4... but both
        // can't exceed. With starts multiple of 4 ≤ 4: max is 0+4.
        let mut m = Milp::new(3, vec![1.0, 1.0, 0.0], true);
        m.set_integer(0, 0.0, 4.0);
        m.set_integer(1, 0.0, 4.0);
        m.set_binary(2);
        // z only multiples of 4: use beta vars implicitly via bounds of a
        // scaled variable — here simply constrain z = 4*b with b binary.
        // Add b1, b2 — extend the model.
        let mut m2 = Milp::new(5, vec![1.0, 1.0, 0.0, 0.0, 0.0], true);
        m2.set_integer(0, 0.0, 4.0);
        m2.set_integer(1, 0.0, 4.0);
        m2.set_binary(2);
        m2.set_binary(3);
        m2.set_binary(4);
        m2.constrain(vec![(0, 1.0), (3, -4.0)], Cmp::Eq, 0.0); // z1 = 4 b1
        m2.constrain(vec![(1, 1.0), (4, -4.0)], Cmp::Eq, 0.0); // z2 = 4 b2
        m2.constrain(vec![(0, 1.0), (1, -1.0), (2, -8.0)], Cmp::Le, -4.0); // z1+4 ≤ z2+8a
        m2.constrain(vec![(1, 1.0), (0, -1.0), (2, 8.0)], Cmp::Le, 4.0); // z2+4 ≤ z1+8(1-a)
        let s = m2.solve_with(NodeBudget::Unlimited).unwrap();
        assert!((s.objective - 4.0).abs() < 1e-6, "{s:?}");
        let _ = m;
    }

    #[test]
    fn node_limit_returns_incumbent_or_none() {
        let mut m = Milp::new(3, vec![60.0, 100.0, 120.0], true);
        m.constrain(vec![(0, 10.0), (1, 20.0), (2, 30.0)], Cmp::Le, 50.0);
        for v in 0..3 {
            m.set_binary(v);
        }
        // Tiny budget may or may not find the optimum but must terminate.
        let _ = m.solve_with(NodeBudget::Nodes(1));
    }

    /// The deprecated sentinel shim maps `0` to unlimited and positive
    /// limits to node caps — legacy callers keep their exact behavior.
    #[test]
    #[allow(deprecated)]
    fn sentinel_shim_matches_solve_with() {
        let mut m = Milp::new(3, vec![60.0, 100.0, 120.0], true);
        m.constrain(vec![(0, 10.0), (1, 20.0), (2, 30.0)], Cmp::Le, 50.0);
        for v in 0..3 {
            m.set_binary(v);
        }
        assert_eq!(NodeBudget::from_limit(0), NodeBudget::Unlimited);
        assert_eq!(NodeBudget::from_limit(7), NodeBudget::Nodes(7));
        assert_eq!(m.solve(0), m.solve_with(NodeBudget::Unlimited));
        assert_eq!(m.solve(2), m.solve_with(NodeBudget::Nodes(2)));
    }

    /// Determinism contract: truncated searches are byte-reproducible —
    /// the same MILP under the same node cap yields the same incumbent,
    /// values and node count on every call, even on a symmetric instance
    /// where many variables tie on fractionality (lowest index wins).
    #[test]
    fn truncated_searches_are_byte_reproducible() {
        // Perfectly symmetric knapsack: every variable is interchangeable,
        // so any tie-break instability would surface as incumbent drift.
        let mut m = Milp::new(6, vec![10.0; 6], true);
        m.constrain((0..6).map(|v| (v, 3.0)).collect(), Cmp::Le, 10.0);
        for v in 0..6 {
            m.set_binary(v);
        }
        m.integral_objective = true;
        let budgets =
            [NodeBudget::Nodes(1), NodeBudget::Nodes(3), NodeBudget::Nodes(10), NodeBudget::Unlimited];
        for budget in budgets {
            let a = m.solve_with(budget);
            let b = m.solve_with(budget);
            let c = m.solve_with(budget);
            assert_eq!(a, b, "{budget:?}: solve is not reproducible");
            assert_eq!(b, c, "{budget:?}: solve is not reproducible");
        }
        // The untruncated optimum packs three items.
        let s = m.solve_with(NodeBudget::Unlimited).unwrap();
        assert!((s.objective - 30.0).abs() < 1e-6, "{s:?}");
    }
}
