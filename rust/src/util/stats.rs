//! Descriptive statistics and the IQR outlier rule from §8.1.

/// Linear-interpolated percentile (`q` in `[0, 100]`) of unsorted data.
pub fn percentile(data: &[f64], q: f64) -> f64 {
    assert!(!data.is_empty(), "percentile of empty slice");
    let mut v: Vec<f64> = data.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Percentile of already-sorted data.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 100.0);
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Arithmetic mean.
pub fn mean(data: &[f64]) -> f64 {
    if data.is_empty() {
        return 0.0;
    }
    data.iter().sum::<f64>() / data.len() as f64
}

/// Population standard deviation.
pub fn std_dev(data: &[f64]) -> f64 {
    if data.len() < 2 {
        return 0.0;
    }
    let m = mean(data);
    (data.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / data.len() as f64).sqrt()
}

/// IQR bounds per §8.1: `[Q1 - 1.5·IQR, Q3 + 1.5·IQR]`.
pub fn iqr_bounds(data: &[f64]) -> (f64, f64) {
    let mut v: Vec<f64> = data.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q1 = percentile_sorted(&v, 25.0);
    let q3 = percentile_sorted(&v, 75.0);
    let iqr = q3 - q1;
    (q1 - 1.5 * iqr, q3 + 1.5 * iqr)
}

/// Retain values inside the IQR bounds (the paper's arrival-time filter).
pub fn iqr_filter(data: &[f64]) -> Vec<f64> {
    if data.is_empty() {
        return Vec::new();
    }
    let (lo, hi) = iqr_bounds(data);
    data.iter().copied().filter(|&x| x >= lo && x <= hi).collect()
}

/// Trapezoidal area under a sampled curve `(x, y)` (Table 6's AUC).
pub fn auc(points: &[(f64, f64)]) -> f64 {
    points
        .windows(2)
        .map(|w| {
            let (x0, y0) = w[0];
            let (x1, y1) = w[1];
            (x1 - x0) * (y0 + y1) / 2.0
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&v, 50.0), 2.5);
        assert_eq!(percentile(&v, 25.0), 1.75);
    }

    #[test]
    fn iqr_filter_removes_outliers() {
        let mut data: Vec<f64> = (0..100).map(|i| i as f64).collect();
        data.push(10_000.0);
        data.push(-10_000.0);
        let kept = iqr_filter(&data);
        assert_eq!(kept.len(), 100);
        assert!(kept.iter().all(|&x| (0.0..100.0).contains(&x)));
    }

    #[test]
    fn iqr_filter_keeps_uniform_data() {
        let data: Vec<f64> = (0..50).map(|i| i as f64).collect();
        assert_eq!(iqr_filter(&data).len(), 50);
    }

    #[test]
    fn auc_rectangle_and_triangle() {
        assert!((auc(&[(0.0, 1.0), (2.0, 1.0)]) - 2.0).abs() < 1e-12);
        assert!((auc(&[(0.0, 0.0), (1.0, 1.0)]) - 0.5).abs() < 1e-12);
        assert_eq!(auc(&[(0.0, 5.0)]), 0.0);
    }

    #[test]
    fn mean_std() {
        let v = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&v) - 5.0).abs() < 1e-12);
        assert!((std_dev(&v) - 2.0).abs() < 1e-12);
    }
}
