//! Property-based testing helper (proptest is unavailable offline).
//!
//! Runs a property over many seeded random cases; on failure it reports
//! the case index and the derived seed so the exact case can be replayed
//! with `PROP_SEED`. Shrinking is intentionally out of scope — failures
//! carry the full generated value via `Debug`.

use crate::util::rng::Rng;

/// Number of cases per property (override with `PROP_CASES`).
pub fn default_cases() -> u32 {
    std::env::var("PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(256)
}

/// Root seed (override with `PROP_SEED` to replay).
pub fn root_seed() -> u64 {
    std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x5EED_CAFE)
}

/// Run `prop` over `default_cases()` random cases. `gen` builds a case
/// from a seeded RNG; `prop` returns `Err(reason)` to fail.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let cases = default_cases();
    let root = root_seed();
    for case in 0..cases {
        let seed = root.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let value = gen(&mut rng);
        if let Err(reason) = prop(&value) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (PROP_SEED={root}, case seed {seed}):\n  \
                 value: {value:?}\n  reason: {reason}"
            );
        }
    }
}

/// Convenience assertion for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            "reverse-reverse-identity",
            |r| (0..r.below(64)).map(|_| r.next_u64()).collect::<Vec<_>>(),
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                if &w == v {
                    Ok(())
                } else {
                    Err("reverse twice changed the vec".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        forall("always-fails", |r| r.next_u64(), |_| Err("nope".into()));
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut first: Vec<u64> = Vec::new();
        forall("collect-1", |r| r.next_u64(), |v| {
            first.push(*v);
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        forall("collect-2", |r| r.next_u64(), |v| {
            second.push(*v);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
