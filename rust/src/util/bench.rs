//! A criterion-style micro-benchmark harness (criterion is unavailable in
//! the offline build environment).
//!
//! Auto-calibrates the iteration count to a target measurement time, runs
//! multiple samples and reports mean / median / p99 plus throughput. All
//! `benches/*.rs` binaries (`harness = false`) are built on this.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    /// Nanoseconds per iteration.
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p99_ns: f64,
    pub std_dev_ns: f64,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl BenchStats {
    /// Iterations per second implied by the mean.
    pub fn throughput(&self) -> f64 {
        1e9 / self.mean_ns
    }

    /// Human-readable single-line summary.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (median {:>10}, p99 {:>10}, {:.2e} it/s)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p99_ns),
            self.throughput(),
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with a shared time budget per benchmark.
pub struct Bench {
    /// Target wall time per sample.
    sample_time: Duration,
    /// Number of samples.
    samples: usize,
    results: Vec<BenchStats>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Bench {
        // Honour the same quick-run env knob everywhere.
        let quick = std::env::var("BENCH_QUICK").is_ok();
        Bench {
            sample_time: if quick { Duration::from_millis(20) } else { Duration::from_millis(120) },
            samples: if quick { 5 } else { 15 },
            results: Vec::new(),
        }
    }

    /// Run `f` repeatedly, timing it; `f`'s return value is black-boxed.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchStats {
        // Calibrate: how many iterations fit in sample_time?
        let mut iters: u64 = 1;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            let dt = t0.elapsed();
            if dt >= self.sample_time / 4 || iters > (1 << 30) {
                let scale = self.sample_time.as_secs_f64() / dt.as_secs_f64().max(1e-9);
                iters = ((iters as f64 * scale).ceil() as u64).max(1);
                break;
            }
            iters *= 8;
        }

        let mut per_iter: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = per_iter.len();
        let mean = per_iter.iter().sum::<f64>() / n as f64;
        let var = per_iter.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let stats = BenchStats {
            name: name.to_string(),
            mean_ns: mean,
            median_ns: per_iter[n / 2],
            p99_ns: per_iter[(n as f64 * 0.99) as usize % n],
            std_dev_ns: var.sqrt(),
            iters_per_sample: iters,
            samples: n,
        };
        println!("{}", stats.line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// Print a compact comparison of two named results as a ratio.
    pub fn compare(&self, base: &str, contender: &str) {
        let find = |n: &str| self.results.iter().find(|r| r.name == n);
        if let (Some(b), Some(c)) = (find(base), find(contender)) {
            println!(
                "  ratio {}/{} = {:.2}x",
                base,
                contender,
                b.mean_ns / c.mean_ns
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bench::new();
        let s = b.run("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..32u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.throughput() > 0.0);
    }

    #[test]
    fn ordering_sane() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bench::new();
        let fast = b.run("fast", || 1u64 + 1).mean_ns;
        let slow = b
            .run("slow", || {
                let mut acc = 0u64;
                for i in 0..20_000u64 {
                    acc = acc.wrapping_add(std::hint::black_box(i));
                }
                acc
            })
            .mean_ns;
        assert!(slow > fast * 10.0, "slow={slow} fast={fast}");
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e4).ends_with("µs"));
        assert!(fmt_ns(5e7).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
