//! Hand-rolled little-endian binary codec for the crash-safe
//! persistence layer ([`crate::recover`]).
//!
//! No external serialization crates: every snapshot payload is a flat
//! little-endian byte stream written by [`Enc`] and read back by
//! [`Dec`]. The writer is infallible (it grows a `Vec<u8>`); the reader
//! returns `Err(String)` on any truncation or malformed field so a torn
//! or corrupt snapshot degrades into a recoverable error instead of a
//! panic — the store falls back to the previous valid snapshot.
//!
//! The codec deliberately carries **no type tags**: reader and writer
//! must agree on the field sequence, and the snapshot frame's version
//! number ([`crate::recover::SNAPSHOT_VERSION`]) is what guards that
//! agreement across releases. Checksumming ([`fnv1a`]) lives at the
//! frame layer, over the whole encoded payload.

/// Little-endian byte-stream writer.
#[derive(Debug, Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    pub fn with_capacity(cap: usize) -> Enc {
        Enc { buf: Vec::with_capacity(cap) }
    }

    /// The encoded bytes so far.
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` travels as `u64` so snapshots are portable across word
    /// sizes.
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// `f64` as its IEEE-754 bit pattern — byte-exact round trips, no
    /// formatting loss.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn bool(&mut self, v: bool) {
        self.u8(u8::from(v));
    }

    /// Length-prefixed raw bytes.
    pub fn blob(&mut self, v: &[u8]) {
        self.usize(v.len());
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, v: &str) {
        self.blob(v.as_bytes());
    }

    /// `Option<u64>` as a presence byte + value.
    pub fn opt_u64(&mut self, v: Option<u64>) {
        match v {
            Some(x) => {
                self.bool(true);
                self.u64(x);
            }
            None => self.bool(false),
        }
    }
}

/// Little-endian byte-stream reader over a borrowed buffer. Every
/// accessor returns `Err` on truncation instead of panicking.
#[derive(Debug)]
pub struct Dec<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, at: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated stream: wanted {n} bytes at offset {}, have {}",
                self.at,
                self.remaining()
            ));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize, String> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| format!("length {v} exceeds the address space"))
    }

    pub fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn bool(&mut self) -> Result<bool, String> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(format!("malformed bool byte {b:#04x}")),
        }
    }

    /// Length-prefixed raw bytes (see [`Enc::blob`]).
    pub fn blob(&mut self) -> Result<&'a [u8], String> {
        let n = self.usize()?;
        self.take(n)
    }

    /// Length-prefixed UTF-8 string (see [`Enc::str`]).
    pub fn str(&mut self) -> Result<String, String> {
        let bytes = self.blob()?;
        String::from_utf8(bytes.to_vec()).map_err(|e| format!("malformed utf-8 string: {e}"))
    }

    pub fn opt_u64(&mut self) -> Result<Option<u64>, String> {
        if self.bool()? {
            Ok(Some(self.u64()?))
        } else {
            Ok(None)
        }
    }

    /// A bounded element count for a collection about to be decoded:
    /// rejects counts that could not possibly fit in the remaining
    /// bytes (each element needs at least `min_elem_bytes`), so a
    /// corrupt length cannot trigger a huge allocation.
    pub fn count(&mut self, min_elem_bytes: usize) -> Result<usize, String> {
        let n = self.usize()?;
        let need = n.saturating_mul(min_elem_bytes.max(1));
        if need > self.remaining() {
            return Err(format!(
                "malformed collection length {n}: needs ≥ {need} bytes, {} remain",
                self.remaining()
            ));
        }
        Ok(n)
    }
}

/// FNV-1a 64-bit hash — the snapshot/journal integrity checksum. Not
/// cryptographic; it detects torn writes and bit rot, which is the
/// failure model of a crash mid-`write`.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_every_scalar() {
        let mut e = Enc::new();
        e.u8(7);
        e.u16(0xBEEF);
        e.u32(0xDEAD_BEEF);
        e.u64(u64::MAX - 3);
        e.usize(42);
        e.f64(-0.125);
        e.bool(true);
        e.bool(false);
        e.str("grmu");
        e.blob(&[1, 2, 3]);
        e.opt_u64(Some(9));
        e.opt_u64(None);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u16().unwrap(), 0xBEEF);
        assert_eq!(d.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64().unwrap(), u64::MAX - 3);
        assert_eq!(d.usize().unwrap(), 42);
        assert_eq!(d.f64().unwrap(), -0.125);
        assert!(d.bool().unwrap());
        assert!(!d.bool().unwrap());
        assert_eq!(d.str().unwrap(), "grmu");
        assert_eq!(d.blob().unwrap(), &[1, 2, 3]);
        assert_eq!(d.opt_u64().unwrap(), Some(9));
        assert_eq!(d.opt_u64().unwrap(), None);
        assert!(d.is_empty());
    }

    #[test]
    fn f64_bit_patterns_survive() {
        for v in [0.0, -0.0, f64::INFINITY, f64::MIN_POSITIVE, 1.0 / 3.0] {
            let mut e = Enc::new();
            e.f64(v);
            let bytes = e.into_bytes();
            let got = Dec::new(&bytes).f64().unwrap();
            assert_eq!(v.to_bits(), got.to_bits());
        }
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut e = Enc::new();
        e.u64(1234);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes[..5]);
        assert!(d.u64().is_err());
        // A truncated length prefix fails the same way.
        let mut e = Enc::new();
        e.str("hello world");
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes[..bytes.len() - 4]);
        assert!(d.str().is_err());
    }

    #[test]
    fn malformed_bool_and_huge_count_rejected() {
        let mut d = Dec::new(&[2]);
        assert!(d.bool().is_err());
        // A length prefix far beyond the buffer must not allocate.
        let mut e = Enc::new();
        e.u64(u64::MAX / 2);
        let bytes = e.into_bytes();
        assert!(Dec::new(&bytes).count(8).is_err());
    }

    #[test]
    fn fnv1a_known_vectors() {
        // Reference values of the 64-bit FNV-1a test suite.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x8594_4171_f739_67e8);
        // Single-bit damage changes the sum.
        assert_ne!(fnv1a(b"snapshot"), fnv1a(b"snapshos"));
    }
}
