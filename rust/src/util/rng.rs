//! Seeded pseudo-random number generation and distributions.
//!
//! Implements PCG-XSH-RR 64/32 (O'Neill 2014) extended to 64-bit output by
//! drawing two 32-bit values, plus `SplitMix64` for seeding. Deterministic
//! across platforms — every experiment in the paper reproduction is keyed
//! by an explicit seed so that all five policies replay the *identical*
//! arrival stream.

/// SplitMix64 — used to expand a single `u64` seed into PCG state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A seeded PCG-XSH-RR generator with distribution helpers.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second normal variate from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let init_inc = splitmix64(&mut sm) | 1;
        let mut rng = Rng { state: 0, inc: init_inc, gauss_spare: None };
        rng.state = init_state.wrapping_add(init_inc);
        let _ = rng.next_u32();
        rng
    }

    /// Derive an independent child generator (stream split).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Expose the full generator state `(state, inc, gauss_spare)` for
    /// the crash-safe snapshot layer. Together with
    /// [`Rng::from_state_parts`] this round-trips the exact stream
    /// position — including the cached Box–Muller spare, which would
    /// otherwise shift every normal variate after a restore.
    pub fn state_parts(&self) -> (u64, u64, Option<f64>) {
        (self.state, self.inc, self.gauss_spare)
    }

    /// Rebuild a generator at an exact stream position captured by
    /// [`Rng::state_parts`].
    pub fn from_state_parts(state: u64, inc: u64, gauss_spare: Option<f64>) -> Rng {
        Rng { state, inc, gauss_spare }
    }

    #[inline]
    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform `u64`.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal variate (Box–Muller, with caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid u == 0 so ln() is finite.
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal variate with the given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std_dev: f64) -> f64 {
        mean + std_dev * self.normal()
    }

    /// Lognormal variate: `exp(N(mu, sigma))`.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_with(mu, sigma).exp()
    }

    /// Exponential variate with the given rate (`lambda`).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Poisson variate. Knuth's method for small `lambda`, normal
    /// approximation (rounded, clamped at 0) for large `lambda`.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let z = self.normal_with(lambda, lambda.sqrt());
            if z < 0.0 {
                0
            } else {
                z.round() as u64
            }
        }
    }

    /// Index drawn according to non-negative `weights` (need not sum to 1).
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        debug_assert!(!weights.is_empty());
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weights must have positive sum");
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            debug_assert!(w >= 0.0);
            if x < w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Uniformly pick a reference from a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_parts_round_trip_resumes_the_exact_stream() {
        let mut a = Rng::new(42);
        // Burn an odd number of normal draws so a Box–Muller spare is
        // cached — the restore must preserve it.
        for _ in 0..7 {
            let _ = a.normal();
        }
        let _ = a.next_u64();
        let (s, i, g) = a.state_parts();
        let mut b = Rng::from_state_parts(s, i, g);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_smoke() {
        let mut r = Rng::new(11);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            // Expected 10_000 each; allow 5% deviation.
            assert!((9_500..10_500).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn poisson_mean_small_lambda() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let lambda = 4.2;
        let s: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
        let mean = s as f64 / n as f64;
        assert!((mean - lambda).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn poisson_mean_large_lambda() {
        let mut r = Rng::new(10);
        let n = 20_000;
        let lambda = 120.0;
        let s: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
        let mean = s as f64 / n as f64;
        assert!((mean - lambda).abs() < 1.0, "mean={mean}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.exponential(2.0)).sum();
        let mean = s / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(17);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0u32; 3];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(23);
        for _ in 0..1_000 {
            assert!(r.lognormal(0.0, 1.5) > 0.0);
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut a = Rng::new(29);
        let mut b = a.split();
        let overlap = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(overlap < 2);
    }
}
