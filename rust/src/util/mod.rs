//! In-tree substrates for the offline build environment.
//!
//! The build image vendors only the `xla` crate's dependency closure, so
//! the ecosystem crates a project like this would normally pull in
//! (`rand`, `serde_json`, `clap`, `criterion`, `proptest`) are replaced by
//! small, tested, purpose-built equivalents:
//!
//! * [`rng`] — a seeded PCG-family PRNG plus the distributions the
//!   workload generator needs (uniform, normal, lognormal, exponential,
//!   Poisson, weighted choice, shuffle).
//! * [`json`] — a JSON value model with serializer and parser, used for
//!   metrics export and config files.
//! * [`cli`] — a minimal subcommand + `--flag value` argument parser.
//! * [`bench`] — a criterion-style timing harness (auto-calibrated
//!   iteration counts, mean/median/p99 reporting).
//! * [`prop`] — a property-testing runner: seeded random cases with
//!   failing-seed reporting.
//! * [`stats`] — descriptive statistics and the IQR outlier rule used by
//!   the trace pipeline (§8.1).
//! * [`codec`] — the little-endian binary writer/reader and FNV-1a
//!   checksum underpinning the crash-safe snapshot layer
//!   (`crate::recover`).

pub mod bench;
pub mod cli;
pub mod codec;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
