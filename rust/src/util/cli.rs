//! Minimal command-line parsing: `binary <subcommand> --key value --flag`.
//!
//! A tiny replacement for `clap` (unavailable offline). Collects the first
//! positional token as the subcommand, remaining positionals in order, and
//! `--key value` / `--switch` options. `--key=value` is also accepted.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token, if any (the subcommand).
    pub command: Option<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
    /// `--key value` and `--key=value` options; bare switches map to "".
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (exclude `argv[0]`).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.options.insert(stripped.to_string(), String::new());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Raw option lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// True if `--key` was present (with or without a value).
    pub fn flag(&self, key: &str) -> bool {
        self.options.contains_key(key)
    }

    /// String option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).map(|s| s.to_string()).unwrap_or_else(|| default.to_string())
    }

    /// Typed option with default; panics with a clear message on bad input.
    pub fn num_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default,
            Some(s) => match s.parse() {
                Ok(v) => v,
                Err(e) => panic!("invalid value for --{key}: {s:?} ({e})"),
            },
        }
    }

    /// Comma-separated list option, e.g. `--caps 20,30,40`.
    pub fn list_or<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| match p.trim().parse() {
                    Ok(v) => v,
                    Err(e) => panic!("invalid element in --{key}: {p:?} ({e})"),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["simulate", "--policy", "grmu", "--seed", "42", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.get("policy"), Some("grmu"));
        assert_eq!(a.num_or("seed", 0u64), 42);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["figures", "--fig=9", "--out=/tmp/x.json"]);
        assert_eq!(a.num_or("fig", 0u32), 9);
        assert_eq!(a.get("out"), Some("/tmp/x.json"));
    }

    #[test]
    fn positionals_collected() {
        let a = parse(&["analyze", "one", "two", "--k", "v", "three"]);
        assert_eq!(a.positional, vec!["one", "two", "three"]);
    }

    #[test]
    fn list_option() {
        let a = parse(&["sweep", "--caps", "20,30,40"]);
        assert_eq!(a.list_or("caps", &[50u32]), vec![20, 30, 40]);
        assert_eq!(a.list_or("other", &[50u32]), vec![50]);
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["run", "--json"]);
        assert!(a.flag("json"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert!(a.command.is_none());
        assert_eq!(a.str_or("policy", "ff"), "ff");
        assert_eq!(a.num_or("seed", 7u64), 7);
    }
}
