//! Checkpoint/recovery cost at 10,000 GPUs (EXPERIMENTS.md §Recovery
//! overhead).
//!
//! Four measurements on a saturated 10k-GPU `EventCore`:
//!
//! 1. **Snapshot encode** — `EventCore::snapshot_bytes` on the live
//!    engine (the pause a checkpointed run takes at each cadence
//!    boundary, before any I/O).
//! 2. **Frame + checksum** — `encode_frame`/`decode_frame` over the
//!    image (the FNV-1a pass dominates).
//! 3. **Durable write** — `SnapshotStore::write` end to end: temp file,
//!    fsync, rename, directory fsync.
//! 4. **Restore** — `EventCore::restore_bytes` from the image back to a
//!    runnable engine (the recovery-path latency floor).
//!
//! Plus the end-to-end overhead: the same trace run with checkpointing
//! off vs a 24-hour cadence, as a wall-clock ratio.
//!
//! Run: `cargo bench --bench recover` (`BENCH_QUICK=1` shrinks the
//! trace; the fleet stays at 10k GPUs).

use grmu::cluster::DataCenter;
use grmu::policies::{PolicyConfig, PolicyCtx, PolicyRegistry};
use grmu::recover::{decode_frame, encode_frame, SnapshotKind, SnapshotStore};
use grmu::report::experiments::{self, ExperimentConfig};
use grmu::sim::EventCore;
use grmu::trace::{TraceConfig, Workload};
use grmu::util::bench::Bench;

const HOSTS: usize = 1_250; // × 8 GPUs = 10,000

fn config(seed: u64, pods: usize, horizon_hours: u64) -> TraceConfig {
    let mut weights = [0.0; 8];
    weights[7] = 1.0; // every host carries 8 GPUs
    TraceConfig {
        seed,
        num_hosts: HOSTS,
        num_pods: pods,
        horizon_hours,
        host_gpu_weights: weights,
        ..TraceConfig::default()
    }
}

/// Drive a fresh core over the trace prefix so the snapshot captures a
/// loaded fleet (resident VMs, samples, RNG cursors, policy state), not
/// an empty one.
fn loaded_core(workload: &Workload, intervals: u64) -> EventCore {
    let policy = PolicyRegistry::standard()
        .build("grmu", &PolicyConfig::new().heavy_frac(0.3))
        .unwrap();
    let mut core =
        EventCore::new(DataCenter::new(workload.hosts.clone()), policy, PolicyCtx::new(7));
    let mut next = 0usize;
    for _ in 0..intervals {
        let t_end = (core.hour() + 1) * core.interval();
        let start = next;
        while next < workload.vms.len() && workload.vms[next].arrival <= t_end {
            next += 1;
        }
        core.step_buffered(&workload.vms[start..next]);
    }
    core
}

fn snapshot_costs(b: &mut Bench, quick: bool) {
    let (pods, horizon) = if quick { (8_000, 24) } else { (40_000, 72) };
    let workload = Workload::generate(config(42, pods, horizon));
    let warm = if quick { 12 } else { 48 };
    let core = loaded_core(&workload, warm);
    let image = core.snapshot_bytes();
    println!(
        "recover/10k-gpus: {} GPUs, {} resident VMs after {warm} intervals, image {:.2} MiB",
        core.dc.num_gpus(),
        core.dc.resident_count(),
        image.len() as f64 / (1024.0 * 1024.0)
    );

    b.run("recover/10k-gpus/snapshot-encode", || core.snapshot_bytes());
    let frame = encode_frame(SnapshotKind::Core, &image);
    b.run("recover/10k-gpus/frame+fnv1a", || encode_frame(SnapshotKind::Core, &image));
    b.run("recover/10k-gpus/frame-verify", || decode_frame(&frame).unwrap().1.len());

    let dir = std::env::temp_dir().join(format!("grmu-bench-recover-{}", std::process::id()));
    let store = SnapshotStore::open(&dir).unwrap();
    b.run("recover/10k-gpus/durable-write(fsync)", || {
        store.write(24, SnapshotKind::Core, &image).unwrap()
    });
    b.run("recover/10k-gpus/restore", || {
        let policy = PolicyRegistry::standard()
            .build("grmu", &PolicyConfig::new().heavy_frac(0.3))
            .unwrap();
        EventCore::restore_bytes(&image, policy).unwrap().hour()
    });
    b.compare("recover/10k-gpus/durable-write(fsync)", "recover/10k-gpus/snapshot-encode");
    let _ = std::fs::remove_dir_all(&dir);
}

/// End-to-end checkpointing overhead: the identical 10k-GPU run with
/// checkpointing off vs a 24-hour cadence (journal every interval, full
/// image every 24). Both runs must produce the same outcome — the
/// overhead is pure persistence cost.
fn end_to_end_overhead(quick: bool) {
    let (pods, horizon) = if quick { (8_000, 24) } else { (40_000, 72) };
    let trace = config(42, pods, horizon);
    let workload = Workload::generate(trace.clone());
    let base_cfg =
        ExperimentConfig { trace: trace.clone(), drain_cap_hours: 24, ..ExperimentConfig::default() };
    let off = experiments::run_once(&workload, "grmu", &base_cfg, true);

    let dir = std::env::temp_dir().join(format!("grmu-bench-recover-e2e-{}", std::process::id()));
    let cp_cfg = ExperimentConfig {
        trace,
        drain_cap_hours: 24,
        checkpoint_every_hours: 24,
        checkpoint_dir: Some(dir.clone()),
        ..ExperimentConfig::default()
    };
    let on = experiments::run_once(&workload, "grmu", &cp_cfg, true);
    assert!(on.same_outcome(&off), "checkpointing changed the outcome");
    let images = SnapshotStore::open(&dir).unwrap().hours().len();
    println!(
        "recover/10k-gpus/end-to-end: off {:.3}s, checkpointed {:.3}s ({} images + journal) = {:+.1}% overhead",
        off.wall_seconds,
        on.wall_seconds,
        images,
        100.0 * (on.wall_seconds / off.wall_seconds.max(1e-9) - 1.0),
    );
    let _ = std::fs::remove_dir_all(&dir);
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let mut b = Bench::new();
    snapshot_costs(&mut b, quick);
    end_to_end_overhead(quick);
}
