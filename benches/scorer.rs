//! Scorer micro-benchmarks — the placement hot path (EXPERIMENTS.md §Perf).
//!
//! Covers the native table-lookup backend, the full Algorithm 1 assign
//! scan, the fragmentation metric, and (when `make artifacts` has run)
//! the XLA/PJRT backend for batch scoring.
//!
//! Run: `cargo bench --bench scorer` (BENCH_QUICK=1 for a fast pass).

use grmu::mig::fragmentation::fragmentation_value;
use grmu::mig::gpu::{cc, profile_capacity};
use grmu::mig::GpuModel;
use grmu::mig::placement::mock_assign;
use grmu::mig::profiles::ALL_PROFILES;
use grmu::policies::mcc::{CcScorer, NativeScorer};
use grmu::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let masks: Vec<u8> = (0..=255).collect();

    b.run("cc/table-lookup-256", || {
        let mut acc = 0u32;
        for &m in &masks {
            acc = acc.wrapping_add(cc(m));
        }
        acc
    });

    b.run("profile-capacity-256", || {
        let mut acc = 0u32;
        for &m in &masks {
            acc = acc.wrapping_add(profile_capacity(m)[2] as u32);
        }
        acc
    });

    b.run("mock-assign/all-profiles-256-masks", || {
        let mut acc = 0u32;
        for &m in &masks {
            for p in ALL_PROFILES {
                if let Some((pl, _)) = mock_assign(m, p) {
                    acc = acc.wrapping_add(pl.start as u32);
                }
            }
        }
        acc
    });

    b.run("fragmentation-value-256", || {
        let mut acc = 0.0f64;
        for &m in &masks {
            acc += fragmentation_value(GpuModel::A100_40, m);
        }
        acc
    });

    // Batch scoring: native backend on a 1024-config batch (the MCC
    // candidate-scan shape at data-center scale).
    let batch: Vec<u8> = (0..1024).map(|i| (i % 256) as u8).collect();
    let mut native = NativeScorer;
    b.run("scorer/native-1024-batch", || native.score(GpuModel::A100_40, &batch));

    #[cfg(feature = "xla")]
    {
        let artifact = std::path::Path::new("artifacts/cc_scorer.hlo.txt");
        if artifact.exists() {
            let mut xla = grmu::runtime::XlaScorer::load(artifact).expect("artifact");
            b.run("scorer/xla-pjrt-1024-batch", || xla.score(GpuModel::A100_40, &batch));
            b.compare("scorer/xla-pjrt-1024-batch", "scorer/native-1024-batch");
        } else {
            eprintln!("(skipping XLA scorer bench: run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "xla"))]
    eprintln!("(skipping XLA scorer bench: built without the `xla` feature)");
}
