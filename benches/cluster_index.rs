//! Large-cluster placement benchmarks: the `ClusterIndex` hot path vs
//! the brute-force full scan at 10,000 GPUs (EXPERIMENTS.md §Perf
//! iterations 5 and 7).
//!
//! The cluster is loaded so that only a small tail of GPUs can host
//! anything — the regime where a per-request O(cluster) scan hurts and
//! the per-profile feasibility buckets pay off. Placements made during a
//! timed batch are removed again inside the iteration, so every
//! iteration sees the same cluster state and the measured cost is the
//! decision path itself (plus the symmetric O(1)/O(log n) index updates
//! both variants pay).
//!
//! The `iter-bucket` rows isolate the index v2 iteration primitives
//! themselves: walking one profile's candidate set through the
//! hierarchical bitset [`grmu::cluster::GpuSetView`], and the same walk
//! word-ANDed against an external [`grmu::cluster::GpuBits`] mask (the
//! shape of GRMU's basket∩bucket intersection). The `grmu` cells then
//! measure that intersection inside the full placement path.
//!
//! Run: `cargo bench --bench cluster_index` (BENCH_QUICK=1 for a fast
//! pass). The acceptance bar for the index refactor is a ≥ 5× speedup
//! per placed batch for the scanning policies at this scale.

use grmu::cluster::vm::VmSpec;
use grmu::cluster::{DataCenter, GpuBits, GpuRef, Host};
use grmu::mig::{GpuModel, Placement, Profile};
use grmu::policies::{Policy, PolicyConfig, PolicyCtx, PolicyRegistry};
use grmu::util::bench::Bench;

const HOSTS: u32 = 1_250;
const GPUS_PER_HOST: usize = 8; // 10,000 GPUs total
const FREE_TAIL_HOSTS: u32 = 2; // only the last 16 GPUs accept anything

/// 10k GPUs, everything full except the last `FREE_TAIL_HOSTS` hosts —
/// a first-fit scan wades through ~9,984 full GPUs per request.
fn loaded_cluster() -> DataCenter {
    let hosts: Vec<Host> = (0..HOSTS).map(|i| Host::new(i, 512, 2_048, GPUS_PER_HOST)).collect();
    let mut dc = DataCenter::new(hosts);
    let mut id = 1u64;
    for h in 0..HOSTS - FREE_TAIL_HOSTS {
        for g in 0..GPUS_PER_HOST {
            let vm = VmSpec {
                id,
                profile: Profile::P7g40gb,
                cpus: 1,
                ram_gb: 1,
                arrival: 0,
                departure: 1_000_000,
                weight: 1.0,
            };
            dc.place(
                &vm,
                GpuRef { host: h, gpu: g as u8 },
                Placement { profile: Profile::P7g40gb, start: 0 },
            );
            id += 1;
        }
    }
    dc
}

/// Mixed-fleet variant: the same 10k-GPU scarcity regime, but hosts
/// cycle A30 / A100-40 / H100-80 parts. The scan walk now wades through
/// both full *and* model-incompatible GPUs, while the per-(model,
/// profile) buckets jump straight to the compatible tail — the index
/// speedup measured under heterogeneity.
fn loaded_mixed_cluster() -> DataCenter {
    const MODELS: [GpuModel; 3] = [GpuModel::A30, GpuModel::A100_40, GpuModel::H100_80];
    let hosts: Vec<Host> = (0..HOSTS)
        .map(|i| {
            let models = vec![MODELS[i as usize % MODELS.len()]; GPUS_PER_HOST];
            Host::with_models(i, 512, 2_048, &models)
        })
        .collect();
    let mut dc = DataCenter::new(hosts);
    let mut id = 1u64;
    for h in 0..HOSTS - FREE_TAIL_HOSTS {
        let model = MODELS[h as usize % MODELS.len()];
        let heavy = model.profile(model.num_profiles() - 1); // whole-GPU GI
        for g in 0..GPUS_PER_HOST {
            let vm = VmSpec {
                id,
                profile: heavy,
                cpus: 1,
                ram_gb: 1,
                arrival: 0,
                departure: 1_000_000,
                weight: 1.0,
            };
            dc.place(
                &vm,
                GpuRef { host: h, gpu: g as u8 },
                Placement { profile: heavy, start: 0 },
            );
            id += 1;
        }
    }
    dc
}

fn probe_batch() -> Vec<VmSpec> {
    (0..64u64)
        .map(|i| VmSpec {
            id: 1_000_000 + i,
            profile: Profile::P1g5gb,
            cpus: 1,
            ram_gb: 1,
            arrival: 0,
            departure: 1_000_000,
            weight: 1.0,
        })
        .collect()
}

fn main() {
    let mut b = Bench::new();
    let registry = PolicyRegistry::standard();
    let mut dc = loaded_cluster();
    let probe = probe_batch();
    println!(
        "cluster: {} GPUs, {} with free blocks; probe batch: {} × 1g.5gb",
        HOSTS as usize * GPUS_PER_HOST,
        dc.index().fitting_count(Profile::P1g5gb),
        probe.len()
    );

    // FF stops at the first fit; MCC must consider every candidate —
    // together they bracket the scanning policies.
    for name in ["ff", "mcc"] {
        for (mode, use_index) in [("indexed", true), ("scan", false)] {
            let cfg = PolicyConfig::new().use_index(use_index);
            let mut policy = registry.build(name, &cfg).unwrap();
            let mut ctx = PolicyCtx::default();
            b.run(&format!("place-batch-64/10k-gpus/{name}/{mode}"), || {
                let decisions = policy.place_batch(&mut dc, &probe, &mut ctx);
                // Undo, so each iteration replays the same state.
                for (vm, d) in probe.iter().zip(&decisions) {
                    if d.is_placed() {
                        dc.remove(vm.id);
                    }
                }
                decisions.len()
            });
        }
        b.compare(
            &format!("place-batch-64/10k-gpus/{name}/scan"),
            &format!("place-batch-64/10k-gpus/{name}/indexed"),
        );
    }

    // Mixed fleet: A30/A100-40/H100-80 in equal thirds, same scarcity.
    // The probe alternates models so every bucket family is exercised.
    let mut dc = loaded_mixed_cluster();
    let probe: Vec<VmSpec> = probe_batch()
        .into_iter()
        .enumerate()
        .map(|(i, mut vm)| {
            let model = [GpuModel::A30, GpuModel::A100_40, GpuModel::H100_80][i % 3];
            vm.profile = model.profile(0); // smallest GI of each model
            vm
        })
        .collect();
    println!(
        "mixed cluster: {} GPUs over 3 models; probe batch: {} × smallest-GI",
        HOSTS as usize * GPUS_PER_HOST,
        probe.len()
    );
    for name in ["ff", "mcc"] {
        for (mode, use_index) in [("indexed", true), ("scan", false)] {
            let cfg = PolicyConfig::new().use_index(use_index);
            let mut policy = registry.build(name, &cfg).unwrap();
            let mut ctx = PolicyCtx::default();
            b.run(&format!("place-batch-64/10k-gpus-mixed/{name}/{mode}"), || {
                let decisions = policy.place_batch(&mut dc, &probe, &mut ctx);
                for (vm, d) in probe.iter().zip(&decisions) {
                    if d.is_placed() {
                        dc.remove(vm.id);
                    }
                }
                decisions.len()
            });
        }
        b.compare(
            &format!("place-batch-64/10k-gpus-mixed/{name}/scan"),
            &format!("place-batch-64/10k-gpus-mixed/{name}/indexed"),
        );
    }

    // Index v2 iteration primitives over a *dense* bucket: an empty
    // fleet leaves all 10k GPUs in the 1g.5gb bucket, so these rows
    // price one candidate step of the hierarchical bitset view — and of
    // the word-AND variant over an every-other-GPU mask (GRMU's
    // basket ∩ bucket shape) — with no placement work attached.
    let dc = DataCenter::new(
        (0..HOSTS).map(|i| Host::new(i, 512, 2_048, GPUS_PER_HOST)).collect(),
    );
    println!(
        "empty cluster: {} GPUs all in the 1g.5gb bucket",
        dc.index().fitting_count(Profile::P1g5gb)
    );
    b.run("iter-bucket/10k-gpus/view", || {
        dc.index().gpus_fitting(Profile::P1g5gb).iter().map(|r| r.host as u64).sum::<u64>()
    });
    let mut mask = GpuBits::for_index(dc.index());
    for (i, r) in dc.index().gpus_fitting(Profile::P1g5gb).iter().enumerate() {
        if i % 2 == 0 {
            mask.insert(dc.index(), r);
        }
    }
    b.run("iter-bucket/10k-gpus/view-and-mask", || {
        dc.index()
            .gpus_fitting(Profile::P1g5gb)
            .and_iter(&mask)
            .map(|r| r.host as u64)
            .sum::<u64>()
    });

    // GRMU end to end in the scarcity regime: the indexed path resolves
    // basket ∩ bucket as a word-wise AND over the bitsets; the scan
    // path probes every basket member against the cluster.
    let mut dc = loaded_cluster();
    let probe = probe_batch();
    for (mode, use_index) in [("indexed", true), ("scan", false)] {
        let cfg = PolicyConfig::new().use_index(use_index);
        let mut policy = registry.build("grmu", &cfg).unwrap();
        let mut ctx = PolicyCtx::default();
        b.run(&format!("place-batch-64/10k-gpus/grmu/{mode}"), || {
            let decisions = policy.place_batch(&mut dc, &probe, &mut ctx);
            for (vm, d) in probe.iter().zip(&decisions) {
                if d.is_placed() {
                    dc.remove(vm.id);
                }
            }
            decisions.len()
        });
    }
    b.compare("place-batch-64/10k-gpus/grmu/scan", "place-batch-64/10k-gpus/grmu/indexed");
}
