//! Placement-decision benchmarks: per-policy batch latency on a loaded
//! mid-size data center — the coordinator's request-path cost.
//!
//! Policies are constructed through the `PolicyRegistry`, so every
//! advertised variant (including `grmu-db`) gets a row.
//!
//! Run: `cargo bench --bench policies`

use grmu::cluster::DataCenter;
use grmu::policies::{Policy, PolicyConfig, PolicyCtx, PolicyRegistry};
use grmu::trace::{TraceConfig, Workload};
use grmu::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let registry = PolicyRegistry::standard();
    let cfg = PolicyConfig::new().heavy_frac(0.15);

    // A 200-host cluster pre-loaded to ~60% with the first half of the
    // trace; then benchmark decisions on the second half.
    let config = TraceConfig {
        num_hosts: 200,
        num_pods: 4_000,
        ..TraceConfig::default()
    };
    let workload = Workload::generate(config);
    let half = workload.vms.len() / 2;
    let (warmup, probe) = workload.vms.split_at(half);
    let probe: Vec<_> = probe.iter().take(512).cloned().collect();

    for name in registry.names() {
        let mut dc = DataCenter::new(workload.hosts.clone());
        let mut policy = registry.build(&name, &cfg).unwrap();
        let mut ctx = PolicyCtx::default();
        policy.place_batch(&mut dc, warmup, &mut ctx);
        // Benchmark: decide the probe batch against a snapshot each time.
        let base = dc.clone();
        b.run(&format!("place-batch-512/{name}"), || {
            let mut dc = base.clone();
            let mut p = registry.build(&name, &cfg).unwrap();
            let mut ctx = PolicyCtx::default();
            ctx.now = 3_600;
            // Rebuild policy state quickly from scratch for GRMU et al.:
            // placement decisions dominate; basket init is O(#GPUs).
            p.place_batch(&mut dc, &probe, &mut ctx)
        });
    }

    // Per-decision latency at full data-center scale (5k GPUs) for the
    // scan-heavy policies — the paper-scale request path.
    let big = Workload::generate(TraceConfig::default());
    let (warm, rest) = big.vms.split_at(big.vms.len() / 2);
    let probe_big: Vec<_> = rest.iter().take(64).cloned().collect();
    for name in ["ff", "mcc", "grmu"] {
        let mut dc = DataCenter::new(big.hosts.clone());
        let mut policy = registry.build(name, &cfg).unwrap();
        let mut ctx = PolicyCtx::default();
        policy.place_batch(&mut dc, warm, &mut ctx);
        let base = dc.clone();
        b.run(&format!("place-batch-64/paper-scale/{name}"), || {
            let mut dc = base.clone();
            let mut p = registry.build(name, &cfg).unwrap();
            let mut ctx = PolicyCtx::default();
            ctx.now = 3_600;
            p.place_batch(&mut dc, &probe_big, &mut ctx)
        });
    }
}
