//! §5.1 analysis benchmarks: configuration-space enumeration and the
//! optimality/improvability sweeps behind the paper's statistics.
//!
//! Run: `cargo bench --bench config_space`

use grmu::mig::config_space::{
    analyze, count_suboptimal, default_policy_reachable, enumerate_all, group_by_multiset,
    two_gpu_analysis, TieBreak,
};
use grmu::util::bench::Bench;

fn main() {
    let mut b = Bench::new();

    b.run("enumerate-all-723", enumerate_all);

    let configs = enumerate_all();
    b.run("group-by-multiset", || group_by_multiset(&configs));

    let groups = group_by_multiset(&configs);
    b.run("count-suboptimal-482", || count_suboptimal(&configs, &groups));

    b.run("default-policy-reachable/first", || {
        default_policy_reachable(TieBreak::First)
    });
    b.run("default-policy-reachable/all-ties", || {
        default_policy_reachable(TieBreak::AllMaximal)
    });

    b.run("analyze/single-gpu", || analyze(false));

    // The 261,726-pair sweep is the heavy one; keep it out of the timed
    // loop in quick mode.
    if std::env::var("BENCH_QUICK").is_err() {
        b.run("two-gpu-analysis/261726-pairs", || two_gpu_analysis(&configs));
    }
}
