//! Rolling-horizon ILP repair throughput at 10,000 GPUs
//! (EXPERIMENTS.md §Optimality gap).
//!
//! Measurements:
//!
//! 1. **Extraction rounds/sec** — ranking the full fleet by
//!    fragmentation and carving the bounded [`PlacementInstance`]
//!    (window + pending rejects), without solving. This is the part of
//!    every online round that scales with fleet size.
//! 2. **Plan rounds/sec vs window size** — one full `RollingIlp`
//!    rejection round (extract → node-budgeted branch-and-bound →
//!    translate) for windows of 4, 8 and 16 GPUs. The solve cost scales
//!    with the window, not the fleet, so this pins the knob's price.
//!
//! Planning never mutates the cluster, so iterations are identical.
//! Run: `cargo bench --bench ilp_online` (`BENCH_QUICK=1` shrinks the
//! fleet).

use grmu::cluster::{DataCenter, GpuRef, Host, VmSpec};
use grmu::ilp::online::{build_instance, fragmented_window, MAX_INSTANCE_VMS, REPAIR_WEIGHT};
use grmu::ilp::RollingIlp;
use grmu::mig::{GpuModel, Placement, Profile};
use grmu::migrate::{MigrationPlan, MigrationPlanner, PlanCtx, PlanScope, PlanTrigger};
use grmu::util::bench::Bench;

fn place(dc: &mut DataCenter, id: u64, profile: Profile, r: GpuRef, start: u8) {
    let vm =
        VmSpec { id, profile, cpus: 1, ram_gb: 1, arrival: 0, departure: 1 << 40, weight: 1.0 };
    dc.place(&vm, r, Placement { profile, start });
}

/// `hosts` × 8 A100-40s, every GPU holding one stray 1g.5gb at block 2 —
/// every device is fragmented, and every stray blocks a 4g.20gb (sole
/// legal start 0), so rejection rounds always find repair work.
fn fragmented_fleet(hosts: u32) -> DataCenter {
    let mut dc = DataCenter::new((0..hosts).map(|i| Host::new(i, 512, 2_048, 8)).collect());
    let mut id = 1u64;
    for h in 0..hosts {
        for g in 0..8u8 {
            place(&mut dc, id, Profile::P1g5gb, GpuRef { host: h, gpu: g }, 2);
            id += 1;
        }
    }
    dc
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let hosts: u32 = if quick { 250 } else { 1_250 }; // × 8 GPUs
    let dc = fragmented_fleet(hosts);
    println!("fleet: {} GPUs, all fragmented (stray 1g in the 4g's blocks)", dc.num_gpus());
    // The rejection burst the planner lays the window out for.
    let pending: Vec<VmSpec> = (0..4)
        .map(|i| VmSpec {
            id: 1_000_000 + i,
            profile: Profile::P4g20gb,
            cpus: 2,
            ram_gb: 8,
            arrival: 0,
            departure: 1 << 40,
            weight: 1.0,
        })
        .collect();
    let mut b = Bench::new();

    // 1. Extraction only: the fleet-size-dependent part of a round.
    b.run("ilp-online/extract/10k-gpus/window-8", || {
        let w = fragmented_window(&dc, PlanScope::Cluster, GpuModel::A100_40, 8);
        let ex = build_instance(&dc, &w, &pending, MAX_INSTANCE_VMS, &|_| REPAIR_WEIGHT);
        assert!(!ex.inst.vms.is_empty());
        ex.inst.vms.len()
    });

    // 2. Full rejection rounds: extract + bounded solve + translate.
    let mut plan = MigrationPlan::new();
    for window in [4usize, 8, 16] {
        let mut planner = RollingIlp::new(window, 20_000, 24);
        let label = format!("ilp-online/plan/10k-gpus/window-{window}");
        b.run(&label, || {
            plan.clear();
            let ctx = PlanCtx {
                now: 0,
                trigger: PlanTrigger::Rejection,
                scope: PlanScope::Cluster,
                pending: &pending,
            };
            planner.plan(&dc, &ctx, &mut plan);
            assert!(!plan.is_empty(), "the strays must be planned out of the 4g's blocks");
            plan.num_moves()
        });
    }
}
