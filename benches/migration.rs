//! Migration-planner throughput at 10,000 GPUs (EXPERIMENTS.md
//! §Planner stacks).
//!
//! Measurements:
//!
//! 1. **Defrag plans/sec** — one `DefragOnReject` planning round over a
//!    fully fragmented 10k-GPU fleet (every GPU carries a stray 1g
//!    instance), with the occupancy fast path + fragmentation table
//!    (`use_index`) vs the full per-GPU recomputation (the brute-force
//!    reference). `Bench::compare` prints the fast-path ratio.
//! 2. **Consolidation plans/sec** — one Algorithm 5 greedy-pairing round
//!    over a fleet of half-full single-profile GPUs (the worst case: the
//!    whole fleet is a candidate), planned against the `PlanView`
//!    overlay without touching the cluster.
//! 3. **FragGradient plans/sec** — one threshold-triggered drain round.
//! 4. **apply_plan + rollback round-trip** — a two-plan ping-pong of one
//!    VM between two GPUs (net-zero state change per iteration), i.e.
//!    the transactional apply's fixed cost per move.
//!
//! Planning never mutates the cluster, so iterations are identical.
//! Run: `cargo bench --bench migration` (`BENCH_QUICK=1` shrinks the
//! fleet).

use grmu::cluster::{DataCenter, GpuRef, Host, VmSpec};
use grmu::mig::{Placement, Profile};
use grmu::migrate::{
    consolidate, DefragOnReject, FragGradient, MigrationPlan, MigrationPlanner, PlanCtx,
    PlanScope, PlanTrigger,
};
use grmu::util::bench::Bench;

fn place(dc: &mut DataCenter, id: u64, profile: Profile, r: GpuRef, start: u8) {
    let vm = VmSpec { id, profile, cpus: 1, ram_gb: 1, arrival: 0, departure: 1 << 40, weight: 1.0 };
    dc.place(&vm, r, Placement { profile, start });
}

/// `hosts` × 8 A100-40s, every GPU holding one stray 1g.5gb at block 4 —
/// maximal defrag pressure (every device is fragmented and repackable).
fn fragmented_fleet(hosts: u32) -> DataCenter {
    let mut dc = DataCenter::new((0..hosts).map(|i| Host::new(i, 512, 2_048, 8)).collect());
    let mut id = 1u64;
    for h in 0..hosts {
        for g in 0..8u8 {
            place(&mut dc, id, Profile::P1g5gb, GpuRef { host: h, gpu: g }, 4);
            id += 1;
        }
    }
    dc
}

/// `hosts` × 8 A100-40s, every GPU half-full with a single 3g.20gb —
/// the whole fleet is an Algorithm 5 candidate.
fn half_full_fleet(hosts: u32) -> DataCenter {
    let mut dc = DataCenter::new((0..hosts).map(|i| Host::new(i, 512, 2_048, 8)).collect());
    let mut id = 1u64;
    for h in 0..hosts {
        for g in 0..8u8 {
            place(&mut dc, id, Profile::P3g20gb, GpuRef { host: h, gpu: g }, 0);
            id += 1;
        }
    }
    dc
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let hosts: u32 = if quick { 250 } else { 1_250 }; // × 8 GPUs
    let mut b = Bench::new();

    // 1. Defrag planning: fast path vs full recomputation.
    let dc = fragmented_fleet(hosts);
    println!("defrag fleet: {} GPUs, all fragmented", dc.num_gpus());
    let mut plan = MigrationPlan::new();
    for (label, use_index) in
        [("migration/defrag-plan/10k-gpus/indexed", true), ("migration/defrag-plan/10k-gpus/scan", false)]
    {
        let mut planner = DefragOnReject::new(use_index);
        b.run(label, || {
            plan.clear();
            let ctx = PlanCtx {
                now: 0,
                trigger: PlanTrigger::Rejection,
                scope: PlanScope::Cluster,
                pending: &[],
            };
            planner.plan(&dc, &ctx, &mut plan);
            assert!(!plan.is_empty());
            plan.num_moves()
        });
    }
    b.compare("migration/defrag-plan/10k-gpus/scan", "migration/defrag-plan/10k-gpus/indexed");

    // 2. Consolidation planning: full-fleet candidate set, overlay-only.
    let dc = half_full_fleet(hosts);
    println!("consolidation fleet: {} GPUs, all half-full candidates", dc.num_gpus());
    b.run("migration/consolidate-plan/10k-gpus", || {
        plan.clear();
        let ctx =
            PlanCtx { now: 0, trigger: PlanTrigger::Tick, scope: PlanScope::Cluster, pending: &[] };
        consolidate::plan_consolidation(&dc, &ctx, &mut plan);
        assert!(plan.num_moves() >= dc.num_gpus() / 2 - 1);
        plan.num_moves()
    });

    // 3. FragGradient planning (drains the worst GPUs per round). Odd
    // GPUs stay empty so downhill destinations exist — the gradient rule
    // refuses equally fragmented targets.
    let mut dc = DataCenter::new((0..hosts).map(|i| Host::new(i, 512, 2_048, 8)).collect());
    let mut id = 1u64;
    for h in 0..hosts {
        for g in (0..8u8).step_by(2) {
            place(&mut dc, id, Profile::P1g5gb, GpuRef { host: h, gpu: g }, 4);
            id += 1;
        }
    }
    println!("frag-gradient fleet: {} GPUs, half fragmented / half empty", dc.num_gpus());
    let mut planner = FragGradient::new(0.1, true).max_gpus(4);
    b.run("migration/frag-gradient-plan/10k-gpus", || {
        plan.clear();
        let ctx =
            PlanCtx { now: 0, trigger: PlanTrigger::Tick, scope: PlanScope::Cluster, pending: &[] };
        planner.plan(&dc, &ctx, &mut plan);
        assert!(!plan.is_empty());
        plan.num_moves()
    });

    // 4. Transactional apply: ping-pong one VM between two GPUs — two
    // single-move plans per iteration, state restored at the end.
    let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
    let (g0, g1) = (GpuRef { host: 0, gpu: 0 }, GpuRef { host: 0, gpu: 1 });
    place(&mut dc, 1, Profile::P3g20gb, g0, 0);
    let pl = Placement { profile: Profile::P3g20gb, start: 0 };
    let mut fwd = MigrationPlan::new();
    fwd.push_migrate(1, g0, g1, pl);
    let mut back = MigrationPlan::new();
    back.push_migrate(1, g1, g0, pl);
    b.run("migration/apply-plan/ping-pong-2-moves", || {
        dc.apply_plan(&fwd).unwrap();
        dc.apply_plan(&back).unwrap();
    });
    dc.check_integrity().unwrap();
}
