//! End-to-end engine throughput at 10,000 GPUs (EXPERIMENTS.md §Perf
//! iteration 6).
//!
//! Three measurements:
//!
//! 1. **Engine requests/sec** — full `EventCore` runs (driven through
//!    `Simulation`) over a synthetic saturated trace on a 10k-GPU fleet,
//!    homogeneous (A100-40) and mixed (A30/A100-40/H100-80), for ff /
//!    mcc / grmu. This is the number that must stay flat as the cluster
//!    grows: the steady-state loop is allocation-free (decisions in the
//!    reusable `DecisionBuffer`, pre-sized heap/samples/migration log)
//!    and scan-free (O(1) activity counters at every interval close).
//!    Each fleet also runs with `use_index: false` — the brute-force
//!    full-scan oracle — so the printed req/s pairs are the end-to-end
//!    before/after of the index v2 hot path (EXPERIMENTS.md §Perf
//!    iteration 7).
//! 2. **Interval-close accounting, before/after** — the per-sample
//!    aggregate reads (`active_hardware_rate`, `active_gpus_by_model`,
//!    `resident_count`) as O(1) counter reads vs the pre-iteration-6
//!    fleet scan (`*_scan`), on a loaded 10k-GPU cluster. The printed
//!    ratio is the sampling-heavy regime's win: the scan cost every
//!    interval O(hosts × GPUs); the counters cost a few loads.
//! 3. **Sweep cells/sec** — the parallel sweep runner's end-to-end cell
//!    throughput with `Arc`-shared per-seed traces.
//!
//! Run: `cargo bench --bench engine` (`BENCH_QUICK=1` shrinks the trace
//! for a fast pass; the fleet stays at 10k GPUs).

use grmu::mig::GpuModel;
use grmu::report::experiments::{self, ExperimentConfig};
use grmu::trace::{TraceConfig, Workload};
use grmu::util::bench::Bench;

const HOSTS: usize = 1_250; // × 8 GPUs = 10,000

/// A 10k-GPU trace config: 1,250 hosts forced to 8 GPUs each, with the
/// default long-lived (lognormal) service times so the fleet saturates
/// early and stays saturated — the regime where per-interval scans and
/// per-batch allocations used to dominate the ~1 ns table-lookup
/// decision cost.
fn config(seed: u64, pods: usize, horizon_hours: u64, mixed: bool) -> TraceConfig {
    let mut weights = [0.0; 8];
    weights[7] = 1.0; // every host carries 8 GPUs
    TraceConfig {
        seed,
        num_hosts: HOSTS,
        num_pods: pods,
        horizon_hours,
        host_gpu_weights: weights,
        gpu_models: if mixed {
            vec![
                (GpuModel::A30, 0.3),
                (GpuModel::A100_40, 0.4),
                (GpuModel::H100_80, 0.3),
            ]
        } else {
            vec![(GpuModel::A100_40, 1.0)]
        },
        ..TraceConfig::default()
    }
}

fn engine_runs(quick: bool) {
    let (pods, horizon) = if quick { (8_000, 24) } else { (60_000, 72) };
    for (fleet, mixed) in [("homogeneous", false), ("mixed", true)] {
        let trace = config(42, pods, horizon, mixed);
        let cfg = ExperimentConfig {
            trace: trace.clone(),
            drain_cap_hours: 24,
            ..ExperimentConfig::default()
        };
        let workload = Workload::generate(trace);
        println!(
            "engine/{fleet}: {} GPUs, {} requests over {horizon}h",
            workload.num_gpus(),
            workload.vms.len()
        );
        for policy in ["ff", "mcc", "grmu"] {
            let result = experiments::run_once(&workload, policy, &cfg, true);
            let rps = if result.wall_seconds > 0.0 {
                result.requested as f64 / result.wall_seconds
            } else {
                f64::INFINITY
            };
            println!(
                "engine/10k-gpus/{fleet}/{policy:<4} {:>9} req in {:>7.3}s = {:>12.0} req/s  (acceptance {:.1}%, {} samples)",
                result.requested,
                result.wall_seconds,
                rps,
                100.0 * result.overall_acceptance(),
                result.samples.len(),
            );
        }
        // Index v2 end to end: the same run through the brute-force
        // scan paths (`--use-index false`). The req/s ratio is the
        // whole-engine win of the hierarchical bitset index — smaller
        // than the per-batch microbench ratio because departures,
        // interval close and trace generation are index-independent.
        for policy in ["ff", "mcc", "grmu"] {
            let scan_cfg = ExperimentConfig { use_index: false, ..cfg.clone() };
            let result = experiments::run_once(&workload, policy, &scan_cfg, true);
            let rps = result.requested as f64 / result.wall_seconds.max(1e-9);
            println!(
                "engine/10k-gpus/{fleet}/{policy:<4} {:>9} req in {:>7.3}s = {:>12.0} req/s  (no index: full-scan oracle)",
                result.requested, result.wall_seconds, rps,
            );
        }
    }
}

/// Sharded-engine scaling at 10k GPUs (EXPERIMENTS.md §Sharding
/// scaling): the same saturated homogeneous trace through the sharded
/// router at 1/2/4/8 shards, plus the router's own overhead — the
/// `shards=1` row runs the identical placement sequence as the classic
/// engine (byte-identical results, locked by tests), so the rows
/// (`run_once` vs router-at-1-shard) isolate the fan-out/merge cost.
fn sharded_runs(quick: bool) {
    let (pods, horizon) = if quick { (8_000, 24) } else { (60_000, 72) };
    let trace = config(42, pods, horizon, false);
    let workload = Workload::generate(trace.clone());
    println!(
        "sharded/10k-gpus: {} GPUs, {} requests over {horizon}h",
        workload.num_gpus(),
        workload.vms.len()
    );
    let unsharded = {
        let cfg = ExperimentConfig {
            trace: trace.clone(),
            drain_cap_hours: 24,
            ..ExperimentConfig::default()
        };
        experiments::run_once(&workload, "grmu", &cfg, true)
    };
    println!(
        "sharded/10k-gpus/grmu/unsharded  {:>9} req in {:>7.3}s = {:>12.0} req/s  (classic engine)",
        unsharded.requested,
        unsharded.wall_seconds,
        unsharded.requested as f64 / unsharded.wall_seconds.max(1e-9),
    );
    let mut base_rps = 0.0f64;
    for shards in [1usize, 2, 4, 8] {
        let cfg = ExperimentConfig {
            trace: trace.clone(),
            drain_cap_hours: 24,
            shards,
            shard_threads: 0, // auto
            ..ExperimentConfig::default()
        };
        let result = experiments::run_sharded(&workload, "grmu", &cfg, true);
        let rps = result.requested as f64 / result.wall_seconds.max(1e-9);
        if shards == 1 {
            base_rps = rps;
            let overhead =
                100.0 * (result.wall_seconds / unsharded.wall_seconds.max(1e-9) - 1.0);
            println!(
                "sharded/10k-gpus/grmu/shards=1   {:>9} req in {:>7.3}s = {:>12.0} req/s  (router overhead {overhead:+.1}% vs classic)",
                result.requested, result.wall_seconds, rps,
            );
        } else {
            println!(
                "sharded/10k-gpus/grmu/shards={shards}   {:>9} req in {:>7.3}s = {:>12.0} req/s  (speedup {:.2}x vs 1 shard, acceptance {:.1}%)",
                result.requested,
                result.wall_seconds,
                rps,
                rps / base_rps.max(1e-9),
                100.0 * result.overall_acceptance(),
            );
        }
    }
}

/// Interval-close aggregate reads on a loaded 10k-GPU mixed cluster:
/// O(1) counters (after) vs the brute-force fleet scan (before). This is
/// exactly what `EventCore::close_interval` pays once per interval.
fn interval_close_accounting(b: &mut Bench) {
    use grmu::cluster::vm::VmSpec;
    use grmu::cluster::{DataCenter, GpuRef, Host};
    use grmu::mig::Placement;

    const MODELS: [GpuModel; 3] = [GpuModel::A30, GpuModel::A100_40, GpuModel::H100_80];
    let hosts: Vec<Host> = (0..HOSTS as u32)
        .map(|i| {
            let models = vec![MODELS[i as usize % MODELS.len()]; 8];
            Host::with_models(i, 512, 2_048, &models)
        })
        .collect();
    let mut dc = DataCenter::new(hosts);
    // Load every GPU with a whole-part GI: every host active, the
    // worst case for the scan.
    let mut id = 1u64;
    for h in 0..HOSTS as u32 {
        let model = MODELS[h as usize % MODELS.len()];
        let heavy = model.profile(model.num_profiles() - 1);
        for g in 0..8u8 {
            let vm = VmSpec {
                id,
                profile: heavy,
                cpus: 1,
                ram_gb: 1,
                arrival: 0,
                departure: 1_000_000,
                weight: 1.0,
            };
            dc.place(&vm, GpuRef { host: h, gpu: g }, Placement { profile: heavy, start: 0 });
            id += 1;
        }
    }
    println!(
        "loaded cluster: {} GPUs on {} hosts, {} resident VMs",
        dc.num_gpus(),
        dc.hosts().len(),
        dc.resident_count()
    );
    b.run("interval-close/10k-gpus/counters(after)", || {
        (dc.active_hardware_rate(), dc.active_gpus_by_model(), dc.resident_count())
    });
    b.run("interval-close/10k-gpus/fleet-scan(before)", || {
        let (active, total) = dc.active_hardware_scan();
        let rate = if total == 0 { 0.0 } else { active as f64 / total as f64 };
        (rate, dc.active_gpus_by_model_scan(), dc.resident_count())
    });
    b.compare(
        "interval-close/10k-gpus/fleet-scan(before)",
        "interval-close/10k-gpus/counters(after)",
    );
}

fn sweep_throughput(quick: bool) {
    let base = ExperimentConfig::quick(0);
    let seeds: Vec<u64> = if quick { vec![1, 2] } else { vec![1, 2, 3, 4] };
    let policies: Vec<String> = if quick {
        vec!["ff".into(), "grmu".into()]
    } else {
        vec!["ff".into(), "mcc".into(), "grmu".into()]
    };
    let cells = seeds.len() * policies.len();
    let t0 = std::time::Instant::now();
    let runs = experiments::sweep(&base, &seeds, &policies, 0);
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(runs.len(), cells);
    println!(
        "sweep/quick-trace: {cells} (seed,policy) cells in {dt:.2}s = {:.2} cells/s (Arc-shared traces)",
        cells as f64 / dt.max(1e-9),
    );
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let mut b = Bench::new();
    engine_runs(quick);
    sharded_runs(quick);
    interval_close_accounting(&mut b);
    sweep_throughput(quick);
}
